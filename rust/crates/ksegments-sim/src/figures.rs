//! Figure regeneration harness: one entry point per figure of the
//! paper's evaluation (DESIGN.md §5 experiment index).
//!
//! Used by both the CLI (`ksegments fig7` etc.) and the `cargo bench`
//! targets, and its rendered tables are what EXPERIMENTS.md records.

use crate::parallel::{eval_cell, parallel_map, EvalGrid, PredictorFactory};
use ksegments_core::predictors::ksegments::RetryStrategy;
use ksegments_core::predictors::MemoryPredictor;
use ksegments_core::scoring::simulate_attempt;
use ksegments_core::trace::Trace;
use ksegments_core::units::{GbSeconds, MemMiB};
use ksegments_core::wastage::{count_wins, render_table, MethodReport};
use ksegments_core::workload::{eager_workflow, generate_workflow_trace, sarek_workflow};

// The `--method` key registry moved to the core layer (the sched
// sweeps need it too, and the crate DAG forbids a sideways sched → sim
// edge); re-exported here so the historical `figures::…` paths keep
// compiling.
pub use ksegments_core::predictors::roster::{
    make_ksegments, make_method, makers_for_keys, method_names, method_roster, resolve_methods,
    FitterChoice, EXTRA_METHOD_KEYS, METHOD_KEYS,
};

/// The two paper workflows generated at a seed.
pub fn paper_traces(seed: u64) -> Vec<Trace> {
    vec![
        generate_workflow_trace(&eager_workflow(), seed),
        generate_workflow_trace(&sarek_workflow(), seed),
    ]
}

/// One method × one fraction over all workflows, merged into one
/// report covering all 33 evaluated tasks.
///
/// Each workflow gets a fresh predictor instance (the paper trains per
/// task type and types are namespaced per workflow, but a fresh
/// instance also resets any cross-task state) — the same per-cell unit
/// the parallel [`EvalGrid`] executes, merged in trace order.
pub fn evaluate_method(
    make: &dyn Fn() -> Box<dyn MemoryPredictor>,
    traces: &[Trace],
    frac: f64,
) -> MethodReport {
    MethodReport::merged(traces.iter().map(|trace| eval_cell(make, trace, frac)))
        .expect("at least one trace")
}

/// Full Fig. 7 grid: every method × every training fraction.
pub struct Fig7Results {
    pub fractions: Vec<f64>,
    /// `by_fraction[i][m]` = report of method m at fraction i.
    pub by_fraction: Vec<Vec<MethodReport>>,
}

/// The Fig. 7 roster as thread-safe factories, in roster order — the
/// method axis of the parallel [`EvalGrid`].
pub fn fig7_makers(choice: FitterChoice) -> Vec<PredictorFactory> {
    makers_for_keys(METHOD_KEYS, choice)
}

/// Run the full Fig. 7 grid (9 methods × 3 fractions × 2 workflows =
/// 54 independent cells) on `workers` threads. Results are identical
/// for any worker count (see `tests/parallel_determinism.rs`).
pub fn run_fig7(seed: u64, choice: FitterChoice, workers: usize) -> Fig7Results {
    run_fig7_selected(seed, choice, workers, METHOD_KEYS)
}

/// [`run_fig7`] over a `--method` subset of the roster (resolved via
/// [`resolve_methods`]), keeping the given key order as row order.
pub fn run_fig7_selected(
    seed: u64,
    choice: FitterChoice,
    workers: usize,
    keys: &[&'static str],
) -> Fig7Results {
    let traces = paper_traces(seed);
    let grid = EvalGrid::new(makers_for_keys(keys, choice), &traces, vec![0.25, 0.5, 0.75]);
    let results = grid.run(workers);
    Fig7Results { fractions: results.fractions, by_fraction: results.by_fraction }
}

impl Fig7Results {
    fn rows(&self, get: impl Fn(&MethodReport) -> f64) -> Vec<(String, Vec<f64>)> {
        let n_methods = self.by_fraction[0].len();
        (0..n_methods)
            .map(|m| {
                (
                    self.by_fraction[0][m].method.clone(),
                    self.by_fraction.iter().map(|frs| get(&frs[m])).collect(),
                )
            })
            .collect()
    }

    /// Fig. 7a: average wastage (GB·s) per method × fraction.
    pub fn render_wastage(&self) -> String {
        render_table(
            "Fig 7a — average wastage per task",
            &self.fractions,
            &self.rows(|r| r.avg_wastage_gbs()),
            "GB·s, mean over evaluated tasks",
        )
    }

    /// Fig. 7b: lowest-wastage win counts per method × fraction.
    pub fn render_wins(&self) -> String {
        let rows: Vec<(String, Vec<f64>)> = {
            let per_frac: Vec<Vec<(String, usize)>> =
                self.by_fraction.iter().map(|frs| count_wins(frs)).collect();
            let n_methods = per_frac[0].len();
            (0..n_methods)
                .map(|m| {
                    (
                        per_frac[0][m].0.clone(),
                        per_frac.iter().map(|w| w[m].1 as f64).collect(),
                    )
                })
                .collect()
        };
        render_table(
            "Fig 7b — # tasks with lowest wastage",
            &self.fractions,
            &rows,
            "count over evaluated tasks (ties award both)",
        )
    }

    /// Fig. 7c: average retries per method × fraction.
    pub fn render_retries(&self) -> String {
        render_table(
            "Fig 7c — average retries per task",
            &self.fractions,
            &self.rows(|r| r.avg_retries()),
            "retries per scored run, mean over evaluated tasks",
        )
    }

    /// §IV-D headline: wastage reduction of the k-Segments strategies
    /// vs the best baseline at the given fraction (paper: 75 % →
    /// 29.48 % Selective / 22.39 % Partial vs PPM Improved).
    pub fn headline(&self, frac: f64) -> String {
        let idx = self
            .fractions
            .iter()
            .position(|f| (f - frac).abs() < 1e-9)
            .expect("fraction not in grid");
        let reports = &self.by_fraction[idx];
        let is_ours = |name: &str| name.starts_with("k-Segments");
        // competitors = everything that is neither ours nor the sanity
        // default — including the zoo rows (Sizey, KS+), so the
        // headline is a true head-to-head against the strongest rival
        let Some((best_base, base_w)) = reports
            .iter()
            .filter(|r| !is_ours(&r.method) && r.method != "Default")
            .map(|r| (r.method.clone(), r.avg_wastage_gbs()))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        else {
            return format!(
                "headline @ {:.0}% training — no baseline rows in this method selection\n",
                frac * 100.0
            );
        };
        let mut out = format!(
            "headline @ {:.0}% training — best baseline: {} ({:.3} GB·s)\n",
            frac * 100.0,
            best_base,
            base_w
        );
        for r in reports.iter().filter(|r| is_ours(&r.method)) {
            let w = r.avg_wastage_gbs();
            let red = 100.0 * (1.0 - w / base_w);
            out.push_str(&format!(
                "  {:<24} {:.3} GB·s  => wastage reduction {:+.2}%\n",
                r.method, w, red
            ));
        }
        out
    }
}

/// Fig. 8: per-task wastage as a function of k (50 % training).
pub struct Fig8Results {
    pub task: String,
    /// `(k, avg wastage GB·s)` pairs.
    pub sweep: Vec<(usize, f64)>,
}

pub fn run_fig8(
    seed: u64,
    choice: FitterChoice,
    task: &str,
    ks: &[usize],
    workers: usize,
) -> Fig8Results {
    let trace = generate_workflow_trace(&eager_workflow(), seed)
        .filtered(|ty| ty == task);
    assert!(trace.n_types() == 1, "task {task} not found in eager trace");
    // one independent cell per k, on the same worker pool as fig7
    let sweep = parallel_map(ks.len(), workers, |i| {
        let k = ks[i];
        let rep = eval_cell(&|| make_ksegments(choice, k, RetryStrategy::Selective), &trace, 0.5);
        (k, rep.avg_wastage_gbs())
    });
    Fig8Results { task: task.to_string(), sweep }
}

impl Fig8Results {
    /// ASCII rendering of the sweep (one bar per k).
    pub fn render(&self) -> String {
        let max = self.sweep.iter().map(|(_, w)| *w).fold(f64::MIN, f64::max);
        let mut out = format!("## Fig 8 — wastage vs k: {}\n\n", self.task);
        for (k, w) in &self.sweep {
            let bar = "#".repeat(((w / max) * 50.0).round() as usize);
            out.push_str(&format!("k={k:>2} {w:>10.3} GB·s |{bar}\n"));
        }
        let best = self
            .sweep
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        out.push_str(&format!("\nglobal optimum at k={} ({:.3} GB·s)\n", best.0, best.1));
        out
    }
}

/// Fig. 4: the predicted step function for adapter removal (k = 4)
/// next to the task's real usage curve.
pub fn run_fig4(seed: u64, choice: FitterChoice) -> String {
    let task = "eager/adapter_removal";
    let trace = generate_workflow_trace(&eager_workflow(), seed).filtered(|ty| ty == task);
    let runs = trace.runs_of(task);
    let n_train = runs.len() / 2;
    let mut m = make_ksegments(choice, 4, RetryStrategy::Selective);
    m.prime(task, trace.default_alloc(task).unwrap());
    for run in &runs[..n_train] {
        m.observe(run);
    }
    let probe = &runs[n_train];
    let alloc = m.predict(task, probe.input_mib);
    let ksegments_core::predictors::Allocation::Dynamic(f) = &alloc else {
        return "model not trained enough for a dynamic allocation".into();
    };
    let mut out = format!(
        "## Fig 4 — k-Segments (k=4) on {task}\n\ninput = {:.1} MiB, true runtime = {}, predicted runtime = {}\n\n",
        probe.input_mib,
        probe.runtime,
        f.predicted_runtime()
    );
    out.push_str("segment boundaries (s): ");
    for b in f.bounds() {
        out.push_str(&format!("{b:.0} "));
    }
    out.push_str("\nsegment allocations (MiB): ");
    for v in f.values() {
        out.push_str(&format!("{v:.0} "));
    }
    out.push('\n');
    // ASCII overlay: allocation (#) vs usage (*) over time
    let width = 64usize;
    let rt = probe.runtime.0.max(f.predicted_runtime().0);
    let peak = f.max_value().max(probe.series.peak());
    out.push_str("\ntime →  (#: allocated, *: used)\n");
    for row in (0..12).rev() {
        let level = peak * (row as f64 + 0.5) / 12.0;
        let mut line = String::with_capacity(width);
        for col in 0..width {
            let t = rt * col as f64 / width as f64;
            let a = f.value_at(t);
            let u = probe.series.value_at(t);
            line.push(if u >= level {
                '*'
            } else if a >= level {
                '#'
            } else {
                ' '
            });
        }
        out.push_str(&format!("{level:>9.0} |{line}\n"));
    }
    out
}

/// Fig. 1: the optimization potential of time-varying allocation on a
/// single bell-shaped execution — peak-static vs usage-hugging.
pub fn run_fig1(seed: u64) -> String {
    let task = "eager/damageprofiler"; // bell profile, like Fig. 1
    let trace = generate_workflow_trace(&eager_workflow(), seed).filtered(|ty| ty == task);
    let run = &trace.runs_of(task)[0];
    let dt = run.series.interval().0;
    let peak = run.series.peak();
    let used: f64 = run.series.samples().iter().map(|u| u * dt).sum();
    let static_alloc = peak * run.runtime.0;
    let optimal_over = 0.0;
    let static_over = static_alloc - used;
    let default_alloc = trace.default_alloc(task).unwrap().0 * run.runtime.0;
    let default_over = default_alloc - used;
    let gbs = |mibs: f64| GbSeconds(MemMiB(mibs).as_gb()).0;
    // sanity: the optimal-peak allocation really succeeds
    let ok = simulate_attempt(
        &run.series,
        &ksegments_core::predictors::Allocation::Static(MemMiB(peak)),
        1,
    )
    .is_success();
    assert!(ok);
    format!(
        "## Fig 1 — optimization potential ({task}, one execution)\n\n\
         runtime: {}, peak usage f(p): {:.0} MiB\n\
         used memory integral:            {:>10.2} GB·s\n\
         optimal (alloc == usage):        {:>10.2} GB·s over-allocation\n\
         best static peak (q = f(p)):     {:>10.2} GB·s over-allocation\n\
         workflow default:                {:>10.2} GB·s over-allocation\n\
         => potential unlocked by time-varying allocation: {:.1}% of the static-peak wastage\n",
        run.runtime,
        peak,
        gbs(used),
        gbs(optimal_over),
        gbs(static_over),
        gbs(default_over),
        100.0 * (1.0 - gbs(optimal_over) / gbs(static_over).max(1e-12)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_has_nine_methods_with_unique_names() {
        let names = method_names();
        assert_eq!(names.len(), METHOD_KEYS.len());
        assert_eq!(names.len(), 9);
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 9);
        assert!(names.contains(&"PPM Improved".to_string()));
        assert!(names.contains(&"k-Segments Selective".to_string()));
        assert!(names.contains(&"Sizey Ensemble".to_string()));
        assert!(names.contains(&"KS+ DynSeg Selective".to_string()));
        assert!(names.contains(&"HTCondor 3x".to_string()));
    }

    #[test]
    fn method_keys_all_construct() {
        for key in METHOD_KEYS.iter().chain(EXTRA_METHOD_KEYS) {
            assert!(make_method(key, FitterChoice::Native).is_some(), "key {key}");
        }
        assert!(make_method("nope", FitterChoice::Native).is_none());
    }

    #[test]
    fn method_selection_resolution() {
        assert_eq!(resolve_methods("all").unwrap(), METHOD_KEYS.to_vec());
        assert_eq!(
            resolve_methods("ensemble,dynseg").unwrap(),
            vec!["ensemble", "dynseg"]
        );
        assert_eq!(
            resolve_methods(" ksegments-adaptive ").unwrap(),
            vec!["ksegments-adaptive"]
        );
        assert!(resolve_methods("bogus").is_err());
        assert!(resolve_methods("").is_err());
    }

    #[test]
    fn fig1_reports_positive_potential() {
        let s = run_fig1(42);
        assert!(s.contains("optimization potential"));
        assert!(s.contains("100.0%")); // optimal removes all static waste
    }

    #[test]
    fn fig8_sweep_shapes() {
        let r = run_fig8(42, FitterChoice::Native, "eager/adapter_removal", &[1, 2, 4], 2);
        assert_eq!(r.sweep.len(), 3);
        // more segments must not be catastrophically worse on the ramp
        let w1 = r.sweep[0].1;
        let w4 = r.sweep[2].1;
        assert!(w4 < w1, "k=4 ({w4}) should beat k=1 ({w1}) on a ramp profile");
        assert!(r.render().contains("global optimum"));
    }

    #[test]
    fn fig4_produces_step_function_plot() {
        let s = run_fig4(42, FitterChoice::Native);
        assert!(s.contains("segment allocations"));
        assert!(s.contains('#'));
        assert!(s.contains('*'));
    }
}
