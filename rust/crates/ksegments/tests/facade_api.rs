//! Facade-surface canary: every module path the pre-workspace
//! `ksegments` crate exposed must still resolve through the facade,
//! with at least one symbol exercised per path.
//!
//! If a workspace refactor drops or renames a re-export, this file is
//! designed to be the first (and loudest) compile failure — before the
//! other integration tests, benches and examples hit the same wall.

use ksegments::prelude::*;

/// Compile-time-only probes for types we don't want to construct here
/// (their functional coverage lives in their own tests).
#[allow(dead_code)]
fn compile_surface(
    _tsdb: &ksegments::tsdb::TsDb,
    _sampler: &ksegments::monitoring::Sampler,
    _step: &ksegments::ml::step_fn::StepFunction,
    _xla: &ksegments::runtime::XlaFitter,
    _ckpt: &ksegments::ingest::Checkpoint,
    _svc: &ksegments::coordinator::ShardedPredictionService,
    _srv: &ksegments::net::NetServer,
    _netc: &ksegments::net::NetClient,
    _lgcfg: &ksegments::net::LoadgenConfig,
    _frame_err: ksegments::net::ErrCode,
    _spec: &ksegments::workflow::WorkflowSpec,
    _grid: &ksegments::sim::EvalGrid,
    _cell: ksegments::sim::EvalCell,
    _ablation: fn(u64, usize) -> String,
) {
}

#[allow(dead_code)]
fn compile_surface_fns() {
    // Reference (don't call) the heavier entry points so their facade
    // paths are type-checked without paying their runtime.
    let _: fn(u64, FitterChoiceAlias) -> String = ksegments::bench_harness::run_fig4;
    let _: fn(u64, usize) -> String = ksegments::bench_harness::ablation::run_all;
    let _: fn(u64, usize) -> String = ksegments::bench_harness::bench_sched_json;
    let _: fn(u64, usize) -> ksegments::bench_harness::FailureSweepResults =
        ksegments::bench_harness::run_failure_sweep;
    let _ = ksegments::ingest::open_source;
    let _ = ksegments::ingest::read_nextflow_dir;
    let _ = ksegments::net::run_loadgen;
    let _ = ksegments::net::parse_request;
    let _ = ksegments::net::export_net_metrics;
    let _: usize = ksegments::net::MAX_FRAME_DEFAULT;
    let _ = ksegments::telemetry::write_chrome_trace;
    let _ = ksegments::sched::schedule_stream;
    let _ = ksegments::sched::schedule_workflows;
}

type FitterChoiceAlias = ksegments::bench_harness::FitterChoice;

fn toy_trace() -> Trace {
    let mut t = Trace::new();
    t.set_default("wf/task", MemMiB(600.0));
    for seq in 0..12u64 {
        let peak = 120.0 + 10.0 * seq as f64;
        t.push(TaskRun {
            task_type: "wf/task".into(),
            input_mib: 50.0 + seq as f64,
            runtime: Seconds(8.0),
            series: UsageSeries::new(2.0, vec![peak * 0.4, peak * 0.8, peak]),
            seq,
        });
    }
    t.sort();
    t
}

#[test]
fn units_rng_util_and_trace_paths_work() {
    // units
    let m = MemMiB::from_gib(1.0);
    assert_eq!(m.0, 1024.0);
    let _: GbSeconds = GbSeconds(1.5);
    // rng
    let mut rng = ksegments::rng::Rng::new(7);
    let x = rng.uniform(1.0, 2.0);
    assert!((1.0..2.0).contains(&x));
    // util (stats + timer through both spellings)
    assert_eq!(ksegments::util::stats::mean(&[1.0, 3.0]), 2.0);
    let sw = ksegments::util::timer::Stopwatch::start();
    let _ = ksegments::bench_harness::timer::Stopwatch::start();
    assert!(sw.elapsed_s() >= 0.0);
    let _ = ksegments::bench_harness::black_box(1u64);
    // trace
    let t = toy_trace();
    assert_eq!(t.n_runs(), 12);
    assert_eq!(t.runs_of("wf/task").len(), 12);
}

#[test]
fn workload_predictors_sim_and_wastage_paths_work() {
    // workload + workflow alias
    let wf: ksegments::workflow::WorkflowSpec = eager_workflow();
    assert!(!wf.tasks.is_empty());
    let _ = sarek_workflow();
    let _: fn(&ksegments::workload::WorkflowSpec, u64) -> Trace = generate_workflow_trace;
    // predictors through the facade roster
    let mut p = ksegments::bench_harness::make_method(
        "default",
        ksegments::bench_harness::FitterChoice::Native,
    )
    .expect("default is a roster key");
    assert!(ksegments::bench_harness::METHOD_KEYS.contains(&"ksegments-selective"));
    // sim (core scoring kernel + sim-layer parallel fan-out, one path)
    let t = toy_trace();
    let cfg = SimConfig::default();
    let report: MethodReport = simulate_trace(&t, p.as_mut(), &cfg);
    assert!(report.total_wastage_gbs() >= 0.0);
    assert!(ksegments::sim::default_workers() >= 1);
    let doubled = ksegments::sim::parallel_map(4, 2, |i| i * 2);
    assert_eq!(doubled, vec![0, 2, 4, 6]);
    // metrics (compat alias) and wastage (canonical) are the same types
    let tr: ksegments::wastage::TaskReport = ksegments::metrics::TaskReport::new("t");
    assert_eq!(tr.task_type, "t");
    let _: &[&str] = ksegments::bench_harness::throughput::THROUGHPUT_KEYS;
    assert!(ksegments::bench_harness::BENCH_AREAS.contains(&"sched"));
}

#[test]
fn ingest_paths_stream_and_materialize() {
    let t = toy_trace();
    let mut src = InMemorySource::from_trace(&t);
    assert_eq!(src.len(), 12);
    let first = src.next_chunk(ksegments::ingest::DEFAULT_CHUNK).unwrap();
    assert_eq!(first.len(), 12);
    src.rewind().unwrap();
    let back = ksegments::ingest::materialize(&mut src).unwrap();
    assert_eq!(back, t);
    // the trait object spelling every consumer uses
    let boxed: Box<dyn TraceSource> = Box::new(InMemorySource::from_trace(&t));
    assert!(boxed.origin().contains("in-memory"));
}

#[test]
fn telemetry_engine_and_sched_paths_work() {
    // telemetry primitives (core) + engine-event bridge (sched layer)
    let mut sink = VecSink::new();
    let ev = ksegments::engine::events::EngineEvent::Completed {
        task_type: "wf/task".into(),
        seq: 3,
        attempts: 1,
    };
    ksegments::telemetry::trace_engine_event(&mut sink, &ev, 1.0);
    assert_eq!(sink.events.len(), 1);
    let mut tel = RunTelemetry::off();
    tel.finish().unwrap();
    let reg = Registry::new();
    let _ = reg.to_json();
    // cluster + sched
    let node = ksegments::cluster::NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 };
    let cfg = SchedConfig {
        policy: ReservationPolicy::SegmentWise,
        nodes: vec![node; 2],
        seed: 42,
        ..SchedConfig::default()
    };
    let t = toy_trace();
    let mut p = ksegments::bench_harness::make_method(
        "default",
        ksegments::bench_harness::FitterChoice::Native,
    )
    .unwrap();
    let rep: SchedReport = ksegments::sched::schedule_trace(&t, p.as_mut(), &cfg);
    assert_eq!(rep.completed, rep.submitted);
}
