//! Task-type and workflow specifications for the synthetic workloads.

use crate::units::{MemMiB, Seconds};
use crate::workload::profiles::ProfileShape;

/// Everything the generator needs to synthesize one task type's
/// executions. Scaling laws are linear in input size (the assumption
/// shared by the paper and all learned baselines), with multiplicative
/// log-normal noise.
#[derive(Debug, Clone)]
pub struct TaskTypeSpec {
    /// Qualified name, e.g. `"eager/adapter_removal"`.
    pub name: String,
    /// Temporal usage profile.
    pub profile: ProfileShape,
    /// Runtime = `rt_base + rt_per_mib · input`, noised.
    pub rt_base: Seconds,
    pub rt_per_mib: f64, // seconds per MiB of input
    /// Peak = `peak_base + peak_per_mib · input`, noised.
    pub peak_base: MemMiB,
    pub peak_per_mib: f64, // MiB of memory per MiB of input
    /// Multiplicative noise sigma (log-space) on runtime and peak.
    pub noise_sigma: f64,
    /// Probability that a run is a "blowup": its peak is multiplied by
    /// a factor in [1.25, 1.7]. Real genomics tools show such
    /// data-dependent memory spikes (duplicated reads, pathological
    /// references); they are what makes pure mean+σ offsetting fail.
    pub spike_prob: f64,
    /// Per-sample temporal wiggle sigma (fraction of local usage).
    pub wiggle_sigma: f64,
    /// Input size distribution: log-normal over MiB.
    pub input_mu: f64,
    pub input_sigma: f64,
    /// Number of executions in the trace.
    pub n_executions: usize,
    /// Workflow developers' default allocation (the sanity baseline) —
    /// deliberately generous so the default never fails (Fig. 7c shows
    /// zero default retries).
    pub default_mem: MemMiB,
}

impl TaskTypeSpec {
    /// Expected input size (median of the log-normal), MiB.
    pub fn median_input_mib(&self) -> f64 {
        self.input_mu.exp()
    }

    /// Nominal (un-noised) runtime at the median input.
    pub fn nominal_runtime(&self) -> Seconds {
        Seconds(self.rt_base.0 + self.rt_per_mib * self.median_input_mib())
    }

    /// Nominal (un-noised) peak at the median input.
    pub fn nominal_peak(&self) -> MemMiB {
        MemMiB(self.peak_base.0 + self.peak_per_mib * self.median_input_mib())
    }

    /// Sanity checks used by catalog tests.
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("empty name".into());
        }
        if self.rt_base.0 < 0.0 || self.rt_per_mib < 0.0 {
            return Err(format!("{}: negative runtime scaling", self.name));
        }
        if self.peak_base.0 <= 0.0 || self.peak_per_mib < 0.0 {
            return Err(format!("{}: non-positive peak scaling", self.name));
        }
        if self.n_executions == 0 {
            return Err(format!("{}: zero executions", self.name));
        }
        if self.default_mem.0 < self.nominal_peak().0 {
            return Err(format!(
                "{}: default {} below nominal peak {} — the sanity baseline must not fail",
                self.name,
                self.default_mem,
                self.nominal_peak()
            ));
        }
        Ok(())
    }
}

/// A workflow: named task types plus dependency edges (indices into
/// `tasks`). The DAG drives submission order in the generated trace
/// (upstream types are submitted in earlier waves, mirroring how
/// Nextflow releases tasks as their inputs become ready).
#[derive(Debug, Clone)]
pub struct WorkflowSpec {
    pub name: String,
    pub tasks: Vec<TaskTypeSpec>,
    /// `(from, to)` edges: `to` consumes outputs of `from`.
    pub edges: Vec<(usize, usize)>,
}

impl WorkflowSpec {
    /// Topological levels (Kahn). Panics on cycles — workflow DAGs are
    /// author-time constants, validated by tests.
    pub fn levels(&self) -> Vec<Vec<usize>> {
        let n = self.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &(f, t) in &self.edges {
            assert!(f < n && t < n, "edge index out of range");
            adj[f].push(t);
            indeg[t] += 1;
        }
        let mut level: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut levels = Vec::new();
        let mut seen = 0;
        while !level.is_empty() {
            seen += level.len();
            let mut next = Vec::new();
            for &u in &level {
                for &v in &adj[u] {
                    indeg[v] -= 1;
                    if indeg[v] == 0 {
                        next.push(v);
                    }
                }
            }
            levels.push(std::mem::take(&mut level));
            level = next;
        }
        assert_eq!(seen, n, "workflow '{}' has a cycle", self.name);
        levels
    }

    pub fn task_index(&self, name: &str) -> Option<usize> {
        self.tasks.iter().position(|t| t.name == name)
    }

    /// Parent adjacency: `parents()[v]` lists every `u` with an edge
    /// `(u, v)` — the tasks whose outputs `v` consumes, i.e. the
    /// completions a dependency-gated scheduler waits for before
    /// releasing `v` (the sched layer's `WorkflowSource`).
    pub fn parents(&self) -> Vec<Vec<usize>> {
        let mut parents: Vec<Vec<usize>> = vec![Vec::new(); self.tasks.len()];
        for &(f, t) in &self.edges {
            assert!(f < self.tasks.len() && t < self.tasks.len(), "edge index out of range");
            parents[t].push(f);
        }
        parents
    }

    pub fn validate(&self) -> Result<(), String> {
        for t in &self.tasks {
            t.validate()?;
        }
        let mut names: Vec<&str> = self.tasks.iter().map(|t| t.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != self.tasks.len() {
            return Err(format!("workflow '{}' has duplicate task names", self.name));
        }
        // levels() panics on cycles; catch via catch_unwind-free check:
        let n = self.tasks.len();
        for &(f, t) in &self.edges {
            if f >= n || t >= n {
                return Err(format!("workflow '{}' edge out of range", self.name));
            }
            if f == t {
                return Err(format!("workflow '{}' self-loop at {f}", self.name));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str) -> TaskTypeSpec {
        TaskTypeSpec {
            name: name.into(),
            profile: ProfileShape::RampUp { alpha: 1.0 },
            rt_base: Seconds(10.0),
            rt_per_mib: 0.01,
            peak_base: MemMiB(100.0),
            peak_per_mib: 0.5,
            noise_sigma: 0.1,
            spike_prob: 0.0,
            wiggle_sigma: 0.02,
            input_mu: 6.0,
            input_sigma: 0.5,
            n_executions: 10,
            default_mem: MemMiB(8192.0),
        }
    }

    #[test]
    fn nominal_quantities() {
        let s = spec("a");
        let med = s.median_input_mib();
        assert!((med - 6.0f64.exp()).abs() < 1e-9);
        assert!((s.nominal_runtime().0 - (10.0 + 0.01 * med)).abs() < 1e-9);
        assert!((s.nominal_peak().0 - (100.0 + 0.5 * med)).abs() < 1e-9);
    }

    #[test]
    fn validate_rejects_undersized_default() {
        let mut s = spec("a");
        s.default_mem = MemMiB(1.0);
        assert!(s.validate().is_err());
    }

    #[test]
    fn levels_of_diamond() {
        let wf = WorkflowSpec {
            name: "w".into(),
            tasks: vec![spec("a"), spec("b"), spec("c"), spec("d")],
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        };
        let lv = wf.levels();
        assert_eq!(lv, vec![vec![0], vec![1, 2], vec![3]]);
        assert!(wf.validate().is_ok());
    }

    #[test]
    fn parents_of_diamond() {
        let wf = WorkflowSpec {
            name: "w".into(),
            tasks: vec![spec("a"), spec("b"), spec("c"), spec("d")],
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        };
        assert_eq!(
            wf.parents(),
            vec![vec![], vec![0], vec![0], vec![1, 2]]
        );
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        let wf = WorkflowSpec {
            name: "w".into(),
            tasks: vec![spec("a"), spec("b")],
            edges: vec![(0, 1), (1, 0)],
        };
        wf.levels();
    }

    #[test]
    fn validate_rejects_duplicates_and_self_loops() {
        let wf = WorkflowSpec {
            name: "w".into(),
            tasks: vec![spec("a"), spec("a")],
            edges: vec![],
        };
        assert!(wf.validate().is_err());
        let wf2 = WorkflowSpec {
            name: "w".into(),
            tasks: vec![spec("a")],
            edges: vec![(0, 0)],
        };
        assert!(wf2.validate().is_err());
    }
}
