//! `ksegments bench` — the committed perf trajectory.
//!
//! One [`BenchSnapshot`] per *area* (`sched`, `replay`, `grid`,
//! `service`). Each snapshot splits hard **counts** — deterministic
//! functions of the seed that must match the committed
//! `BENCH_<area>.json` exactly, at any worker count — from soft
//! **throughput** — wall-clock dependent, compared with a noise
//! threshold. CI runs `ksegments bench --area sched --area replay`
//! per push and `tools/bench_check.py` diffs the result against the
//! committed trajectory (exact on counts, ±20 % on throughput once a
//! snapshot is calibrated; committed snapshots start `provisional`).
//!
//! All wall time flows through [`Stopwatch`] — the sim-time vs
//! wall-time rule of DESIGN.md §12.

use crate::bench_harness::figures::{make_method, run_fig7_selected, FitterChoice};
use crate::bench_harness::throughput::{run_failure_sweep, FailureSweepResults};
use crate::bench_harness::timer::Stopwatch;
use crate::coordinator::ShardedPredictionService;
use crate::ingest::{replay_source, InMemorySource, ReplayConfig};
use crate::util::json::Json;
use crate::workload::{eager_workflow, generate_workflow_trace};

/// The benched areas, in `BENCH_<area>.json` naming order.
pub const BENCH_AREAS: &[&str] = &["sched", "replay", "grid", "service"];

/// Bumped whenever a snapshot's counts change meaning — the checker
/// refuses to compare snapshots across schema versions.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// The predictor every non-sweep area benches (the paper's headline
/// method; the `sched` area sweeps the full roster instead).
const BENCH_METHOD: &str = "ksegments-selective";

/// One area's perf snapshot, rendered to `BENCH_<area>.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchSnapshot {
    pub area: &'static str,
    pub seed: u64,
    pub workers: usize,
    /// Deterministic work counters, in render order — CI requires an
    /// exact match against the committed snapshot.
    pub counts: Vec<(&'static str, u64)>,
    /// Wall time of the benched section (seconds) — context only.
    pub wall_s: f64,
    /// The headline rate (work items per wall second) — compared with
    /// a noise threshold, never exactly.
    pub throughput: f64,
    pub throughput_unit: &'static str,
}

impl BenchSnapshot {
    pub fn count(&self, name: &str) -> Option<u64> {
        self.counts.iter().find(|(k, _)| *k == name).map(|&(_, v)| v)
    }

    /// Canonical snapshot file name (`BENCH_sched.json`, ...).
    pub fn file_name(&self) -> String {
        format!("BENCH_{}.json", self.area)
    }

    /// The committed-snapshot JSON document. A freshly measured
    /// snapshot is never provisional; committed placeholders flip the
    /// flag by hand until a real CI runner calibrates them.
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("bench", self.area.into()),
            ("schema", BENCH_SCHEMA_VERSION.into()),
            ("seed", self.seed.into()),
            ("workers", (self.workers as u64).into()),
            ("provisional", false.into()),
            (
                "counts",
                Json::Obj(
                    self.counts.iter().map(|&(k, v)| (k.to_string(), Json::from(v))).collect(),
                ),
            ),
            ("wall_s", self.wall_s.into()),
            ("throughput", self.throughput.into()),
            ("throughput_unit", self.throughput_unit.into()),
        ])
        .to_string()
    }
}

/// Run one bench area. `Err` only for an unknown area name or a
/// mid-bench I/O failure; the measured snapshot is otherwise total.
pub fn run_bench_area(area: &str, seed: u64, workers: usize) -> Result<BenchSnapshot, String> {
    match area {
        "sched" => Ok(bench_sched(seed, workers)),
        "replay" => bench_replay(seed, workers),
        "grid" => Ok(bench_grid(seed, workers)),
        "service" => bench_service(seed, workers),
        other => Err(format!("unknown bench area {other:?} (expected one of {BENCH_AREAS:?})")),
    }
}

/// Fold an already-run failure sweep into the `sched` snapshot — the
/// testable seam ([`bench_sched`] adds the wall clock around it).
pub fn sched_snapshot(
    sweep: &FailureSweepResults,
    seed: u64,
    workers: usize,
    wall_s: f64,
) -> BenchSnapshot {
    let events: u64 = sweep.results.reports.iter().map(|r| r.events_processed).sum();
    let completed: u64 = sweep.results.reports.iter().map(|r| r.completed).sum();
    let node_failures: u64 = sweep.results.reports.iter().map(|r| r.node_failures).sum();
    BenchSnapshot {
        area: "sched",
        seed,
        workers,
        counts: vec![
            ("n_cells", sweep.results.reports.len() as u64),
            ("events_processed", events),
            ("tasks_completed", completed),
            ("node_failures", node_failures),
        ],
        wall_s,
        throughput: events as f64 / wall_s.max(1e-9),
        throughput_unit: "events_per_s",
    }
}

/// Scheduler engine throughput over the full failure-domain sweep.
fn bench_sched(seed: u64, workers: usize) -> BenchSnapshot {
    let sw = Stopwatch::start();
    let sweep = run_failure_sweep(seed, workers);
    sched_snapshot(&sweep, seed, workers, sw.elapsed_s())
}

/// Streaming-replay throughput: the eager workflow trace through the
/// sharded replay pipeline under the headline predictor.
fn bench_replay(seed: u64, workers: usize) -> Result<BenchSnapshot, String> {
    let trace = generate_workflow_trace(&eager_workflow(), seed);
    let mut src = InMemorySource::from_trace(&trace);
    let make = || make_method(BENCH_METHOD, FitterChoice::Native).expect("known method key");
    let cfg = ReplayConfig::default();
    let sw = Stopwatch::start();
    let out = replay_source(&mut src, &make, &cfg, workers, None)
        .map_err(|e| format!("replay bench failed: {e}"))?;
    let wall_s = sw.elapsed_s();
    let scored: u64 = out.report.tasks.iter().map(|t| t.n_scored as u64).sum();
    Ok(BenchSnapshot {
        area: "replay",
        seed,
        workers,
        counts: vec![
            ("runs_replayed", out.runs_replayed),
            ("runs_warmup", out.runs_warmup),
            ("tasks_scored", scored),
            ("retries", out.report.total_retries()),
        ],
        wall_s,
        throughput: out.runs_replayed as f64 / wall_s.max(1e-9),
        throughput_unit: "runs_per_s",
    })
}

/// Evaluation-grid throughput: a small Fig. 7 roster over the paper
/// workflows at all three training fractions.
fn bench_grid(seed: u64, workers: usize) -> BenchSnapshot {
    let keys: &[&'static str] = &["default", BENCH_METHOD];
    let sw = Stopwatch::start();
    let fig7 = run_fig7_selected(seed, FitterChoice::Native, workers, keys);
    let wall_s = sw.elapsed_s();
    let n_cells = (fig7.fractions.len() * keys.len()) as u64;
    let scored: u64 = fig7
        .by_fraction
        .iter()
        .flatten()
        .flat_map(|r| &r.tasks)
        .map(|t| t.n_scored as u64)
        .sum();
    BenchSnapshot {
        area: "grid",
        seed,
        workers,
        counts: vec![("n_cells", n_cells), ("tasks_scored", scored)],
        wall_s,
        throughput: n_cells as f64 / wall_s.max(1e-9),
        throughput_unit: "cells_per_s",
    }
}

/// Sharded prediction-service throughput: the eager trace streamed
/// through `workers` shards (predict + complete per run). Wakeup
/// counts are scheduling-dependent and deliberately **not** counted.
fn bench_service(seed: u64, workers: usize) -> Result<BenchSnapshot, String> {
    let trace = generate_workflow_trace(&eager_workflow(), seed);
    let mut src = InMemorySource::from_trace(&trace);
    let sw = Stopwatch::start();
    let svc = ShardedPredictionService::spawn(workers.max(1), |_| {
        make_method(BENCH_METHOD, FitterChoice::Native).expect("known method key")
    });
    let fed = svc
        .handle()
        .replay_source(&mut src, 256)
        .map_err(|e| format!("service bench failed: {e}"))?;
    let stats = svc.shutdown();
    let wall_s = sw.elapsed_s();
    Ok(BenchSnapshot {
        area: "service",
        seed,
        workers,
        counts: vec![
            ("runs_fed", fed),
            ("predictions", stats.predictions),
            ("completions", stats.completions),
        ],
        wall_s,
        throughput: stats.predictions as f64 / wall_s.max(1e-9),
        throughput_unit: "predictions_per_s",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::throughput::{run_failure_sweep_axes, THROUGHPUT_KEYS};

    #[test]
    fn unknown_area_is_rejected() {
        let err = run_bench_area("nope", 42, 2).unwrap_err();
        assert!(err.contains("unknown bench area"), "{err}");
        assert!(err.contains("sched"), "{err}");
    }

    #[test]
    fn sched_snapshot_is_valid_and_counts_events() {
        let t = run_failure_sweep_axes(42, &[0.0, 0.01], &[None], 2);
        let snap = sched_snapshot(&t, 42, 2, 1.5);
        let j = Json::parse(&snap.to_json()).expect("bench json parses");
        assert_eq!(j.get("bench").as_str(), Some("sched"));
        assert_eq!(j.get("schema").as_u64(), Some(BENCH_SCHEMA_VERSION));
        assert_eq!(j.get("seed").as_u64(), Some(42));
        assert_eq!(j.get("provisional").as_bool(), Some(false));
        let counts = j.get("counts");
        assert_eq!(counts.get("n_cells").as_u64(), Some((THROUGHPUT_KEYS.len() * 2) as u64));
        // every simulated event is counted — a scheduling run always
        // processes at least one event per admitted task
        let events = counts.get("events_processed").as_u64().unwrap();
        let tasks = counts.get("tasks_completed").as_u64().unwrap();
        assert!(events >= tasks, "{events} events < {tasks} tasks");
        assert!(tasks > 0);
        assert!((j.get("throughput").as_f64().unwrap() - events as f64 / 1.5).abs() < 1e-6);
        assert_eq!(j.get("throughput_unit").as_str(), Some("events_per_s"));
        assert_eq!(snap.count("events_processed"), Some(events));
        assert_eq!(snap.count("missing"), None);
        assert_eq!(snap.file_name(), "BENCH_sched.json");
    }

    #[test]
    fn replay_counts_are_worker_count_independent() {
        let a = run_bench_area("replay", 42, 1).expect("replay area runs");
        let b = run_bench_area("replay", 42, 4).expect("replay area runs");
        assert_eq!(a.counts, b.counts, "counts must not depend on shard count");
        assert!(a.count("runs_replayed").unwrap() > 0);
        assert!(a.throughput > 0.0);
        let j = Json::parse(&a.to_json()).expect("valid json");
        assert_eq!(j.get("bench").as_str(), Some("replay"));
        assert_eq!(j.get("throughput_unit").as_str(), Some("runs_per_s"));
    }

    #[test]
    fn service_counts_match_the_stream() {
        let snap = run_bench_area("service", 42, 2).expect("service area runs");
        let fed = snap.count("runs_fed").unwrap();
        assert!(fed > 0);
        assert_eq!(snap.count("predictions"), Some(fed));
        assert_eq!(snap.count("completions"), Some(fed));
    }
}
