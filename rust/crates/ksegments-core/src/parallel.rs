//! The deterministic fixed-size worker pool shared by every fan-out
//! surface in the workspace — the sim evaluation grid, the scheduler
//! sweep grids, and the streaming replay engine all claim work through
//! [`parallel_map`].
//!
//! It lives in the core layer (rather than `ksegments-sim`, its
//! pre-split home) because the crate DAG enforced by `ksegments-lint`
//! allows sim, sched and serve to depend on core only: a shared pool
//! anywhere higher would force a sideways dependency between peers.
//!
//! Determinism is load-bearing (every number in EXPERIMENTS.md is
//! regenerated from a fixed seed): callers must make each work item a
//! pure function of its index, and [`parallel_map`] re-orders results
//! by index before returning, so `workers = 1` and `workers = N` are
//! bit-identical by construction — `tests/parallel_determinism.rs`
//! locks this down for every grid built on top.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::predictors::MemoryPredictor;

/// A thread-safe predictor constructor: each grid cell (and each
/// service shard) builds its own private model instance from one of
/// these, so no model state is ever shared between threads.
pub type PredictorFactory = Box<dyn Fn() -> Box<dyn MemoryPredictor> + Send + Sync>;

/// Default worker-pool size: one worker per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Order-preserving parallel map over `0..n` on a fixed-size pool of
/// `workers` std threads.
///
/// Work is claimed dynamically (atomic counter), so stragglers don't
/// serialise the pool, but the output vector is always `[f(0), f(1),
/// ..., f(n-1)]` regardless of which worker computed which index.
/// `workers <= 1` degenerates to a plain sequential map with no thread
/// setup. A panic in any `f(i)` propagates to the caller.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                results.lock().unwrap().push((i, r));
            });
        }
    });
    let mut pairs = results.into_inner().unwrap();
    pairs.sort_by_key(|(i, _)| *i);
    debug_assert_eq!(pairs.len(), n);
    pairs.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        for workers in [1, 2, 4, 9] {
            let out = parallel_map(100, workers, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn parallel_map_empty_and_oversubscribed() {
        assert!(parallel_map(0, 8, |i| i).is_empty());
        assert_eq!(parallel_map(3, 64, |i| i + 1), vec![1, 2, 3]);
    }
}
