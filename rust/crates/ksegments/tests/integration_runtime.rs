//! Integration: the AOT JAX + Pallas fit modules executed through the
//! PJRT runtime, differential-tested against the native f64 mirror.
//!
//! Requires `make artifacts`; every test is skipped (with a notice)
//! when the artifacts directory is absent so `cargo test` stays green
//! on a fresh checkout.

use std::path::Path;

use ksegments::ml::fitter::{FitInput, KsegFitter, NativeFitter};
use ksegments::predictors::ksegments::{KSegmentsConfig, KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::MemoryPredictor;
use ksegments::rng::Rng;
use ksegments::runtime::{ArtifactRegistry, XlaFitter};
use ksegments::sim::{simulate_trace, SimConfig};
use ksegments::units::MemMiB;
use ksegments::workload::{eager_workflow, generate_workflow_trace};

fn artifacts_available() -> bool {
    if !cfg!(feature = "xla") {
        eprintln!("SKIP: built without the `xla` feature — PJRT runtime gated off");
        return false;
    }
    let ok = Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
    }
    ok
}

fn synth_input(n: usize, t: usize, seed: u64) -> FitInput {
    let mut rng = Rng::new(seed);
    let mut input = FitInput::default();
    for _ in 0..n {
        let x = rng.uniform(50.0, 8000.0);
        let peak = 20.0 + 0.6 * x * rng.uniform(0.8, 1.25);
        input.x.push(x);
        input.runtime.push(10.0 + 0.04 * x * rng.uniform(0.9, 1.1));
        input
            .series
            .push((0..t).map(|j| peak * ((j + 1) as f64 / t as f64).powf(0.7)).collect());
    }
    input
}

#[test]
fn manifest_matches_python_constants() {
    if !artifacts_available() {
        return;
    }
    let reg = ArtifactRegistry::load_default().unwrap();
    // python/compile/model.py: N_HIST = 64, T_MAX = 256, K_RANGE = 1..=16
    assert_eq!(reg.manifest().n_hist, 64);
    assert_eq!(reg.manifest().t_max, 256);
    assert_eq!(reg.available_ks(), (1..=16).collect::<Vec<_>>());
}

#[test]
fn xla_fit_matches_native_across_k() {
    if !artifacts_available() {
        return;
    }
    let mut xla = XlaFitter::load_default().unwrap();
    let mut native = NativeFitter;
    let t_max = xla.manifest().t_max;
    for (seed, k) in [(1u64, 1usize), (2, 2), (3, 4), (4, 7), (5, 12), (6, 16)] {
        let input = synth_input(32, t_max, seed);
        let a = xla.fit(&input, k);
        let b = native.fit(&input, k);
        let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1.0);
        assert!(rel(a.rt.a, b.rt.a) < 1e-3, "k={k}: rt.a {} vs {}", a.rt.a, b.rt.a);
        assert!(rel(a.rt.b, b.rt.b) < 1e-3, "k={k}: rt.b");
        assert!(rel(a.rt_offset, b.rt_offset) < 1e-2, "k={k}: rt_offset");
        for s in 0..k {
            assert!(rel(a.seg[s].a, b.seg[s].a) < 1e-3, "k={k} s={s}: seg.a");
            assert!(rel(a.seg[s].b, b.seg[s].b) < 1e-3, "k={k} s={s}: seg.b");
            assert!(rel(a.seg_off[s], b.seg_off[s]) < 1e-2, "k={k} s={s}: seg_off");
        }
    }
    assert_eq!(xla.native_fits, 0, "all fits must run on the XLA path");
}

#[test]
fn xla_fit_handles_short_history_padding() {
    if !artifacts_available() {
        return;
    }
    let mut xla = XlaFitter::load_default().unwrap();
    let mut native = NativeFitter;
    let t_max = xla.manifest().t_max;
    for n in [1usize, 2, 3, 63, 64] {
        let input = synth_input(n, t_max, 100 + n as u64);
        let a = xla.fit(&input, 4);
        let b = native.fit(&input, 4);
        let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1.0);
        assert!(rel(a.seg[3].a, b.seg[3].a) < 2e-3, "n={n}: {} vs {}", a.seg[3].a, b.seg[3].a);
        assert!(rel(a.seg[3].b, b.seg[3].b) < 2e-3, "n={n}");
    }
}

#[test]
fn xla_fit_windows_history_beyond_n_hist() {
    if !artifacts_available() {
        return;
    }
    let mut xla = XlaFitter::load_default().unwrap();
    let t_max = xla.manifest().t_max;
    let n_hist = xla.manifest().n_hist;
    // 100 rows: the artifact keeps the most recent 64; compare against
    // native fit on exactly those rows
    let input = synth_input(100, t_max, 9);
    let a = xla.fit(&input, 4);
    let tail = FitInput {
        x: input.x[100 - n_hist..].to_vec(),
        runtime: input.runtime[100 - n_hist..].to_vec(),
        series: input.series[100 - n_hist..].to_vec(),
    };
    let b = NativeFitter.fit(&tail, 4);
    let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1.0);
    assert!(rel(a.seg[3].a, b.seg[3].a) < 2e-3);
    assert!(rel(a.rt.b, b.rt.b) < 2e-3);
}

#[test]
fn unsupported_shapes_fall_back_to_native() {
    if !artifacts_available() {
        return;
    }
    let mut xla = XlaFitter::load_default().unwrap();
    // wrong series length -> native fallback, still correct
    let input = synth_input(8, 64, 11);
    let a = xla.fit(&input, 4);
    let b = NativeFitter.fit(&input, 4);
    assert_eq!(a, b);
    assert_eq!(xla.native_fits, 1);
    assert_eq!(xla.xla_fits, 0);
}

#[test]
fn end_to_end_sim_with_xla_backed_predictor_matches_native_shape() {
    if !artifacts_available() {
        return;
    }
    // The full evaluation protocol with the production (XLA) fitter:
    // results must be within a whisker of the native-fit run (f32 vs
    // f64 only).
    let trace = generate_workflow_trace(&eager_workflow(), 42)
        .filtered(|ty| ty == "eager/adapter_removal" || ty == "eager/qualimap");
    let cfg = SimConfig::with_training_frac(0.5);

    let xla_fitter: Box<dyn KsegFitter> = Box::new(XlaFitter::load_default().unwrap());
    let mut with_xla = KSegmentsPredictor::with_fitter(
        xla_fitter,
        KSegmentsConfig::default(),
        RetryStrategy::Selective,
    );
    let mut with_native = KSegmentsPredictor::native(4, RetryStrategy::Selective);

    let rep_xla = simulate_trace(&trace, &mut with_xla, &cfg);
    let rep_native = simulate_trace(&trace, &mut with_native, &cfg);
    let (a, b) = (rep_xla.avg_wastage_gbs(), rep_native.avg_wastage_gbs());
    assert!(
        (a - b).abs() / b < 0.02,
        "xla-backed wastage {a} deviates from native {b}"
    );
}

#[test]
fn predictor_with_xla_fitter_serves_dynamic_allocations() {
    if !artifacts_available() {
        return;
    }
    let fitter: Box<dyn KsegFitter> = Box::new(XlaFitter::load_default().unwrap());
    let mut p = KSegmentsPredictor::with_fitter(
        fitter,
        KSegmentsConfig::default(),
        RetryStrategy::Partial,
    );
    p.prime("t", MemMiB(4096.0));
    let trace = generate_workflow_trace(&eager_workflow(), 1);
    for run in &trace.runs_of("eager/adapter_removal")[..16] {
        let mut r = run.clone();
        r.task_type = "t".into();
        p.observe(&r);
    }
    let alloc = p.predict("t", 1000.0);
    assert!(alloc.is_dynamic());
    assert!(alloc.max_value() >= 100.0);
}
