//! End-to-end ingestion + streaming-replay suite over the checked-in
//! Nextflow fixture (`tests/fixtures/nextflow`): parser shape, the
//! ingest → jsonl → read round-trip property, worker-count bit
//! identity of the replay engine across every source kind, and
//! warm-start-equals-cold checkpointing.

use std::path::{Path, PathBuf};

use ksegments::bench_harness::{make_method, FitterChoice};
use ksegments::ingest::{
    materialize, read_nextflow_dir, replay_source, Checkpoint, InMemorySource, JsonlReader,
    NextflowDirSource, ReplayConfig, TraceSource,
};
use ksegments::predictors::ppm::PpmPredictor;
use ksegments::predictors::MemoryPredictor;
use ksegments::rng::Rng;
use ksegments::sched::{schedule_stream, schedule_trace, SchedConfig};
use ksegments::trace::{
    read_trace_jsonl, write_trace_jsonl, write_trace_jsonl_ordered, TaskRun, Trace, UsageSeries,
};
use ksegments::units::{MemMiB, Seconds};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/nextflow")
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ksegments_test_ingest_replay");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn kseg_factory() -> Box<dyn MemoryPredictor> {
    make_method("ksegments-selective", FitterChoice::Native).expect("roster key")
}

#[test]
fn fixture_parses_to_expected_shape() {
    let mut src = NextflowDirSource::open(&fixture_dir()).unwrap();
    assert_eq!(src.n_rows(), 14, "14 COMPLETED rows");
    assert_eq!(src.skipped_rows(), 2, "FAILED + CACHED rows skipped");
    // requested-memory defaults per process
    let defaults = src.defaults();
    let names: Vec<&str> = defaults.iter().map(|(ty, _)| ty.as_str()).collect();
    assert_eq!(names, vec!["ALIGN", "FILTER", "QUANT"]);
    assert_eq!(defaults[0].1, MemMiB::parse("2 GB").unwrap());

    let trace = materialize(&mut src).unwrap();
    assert_eq!(trace.n_types(), 3);
    assert_eq!(trace.n_runs(), 14);
    assert_eq!(trace.runs_of("ALIGN").len(), 5);
    assert_eq!(trace.runs_of("QUANT").len(), 5);
    assert_eq!(trace.runs_of("FILTER").len(), 4);

    // submit-ordered seq: the first two arrivals are ALIGN then QUANT
    let ordered = trace.all_runs_ordered();
    assert_eq!(ordered[0].task_type, "ALIGN");
    assert_eq!(ordered[1].task_type, "QUANT");
    let seqs: Vec<u64> = ordered.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, (0..14).collect::<Vec<u64>>());

    // the nf-core-reality rows: an ms-duration FILTER and a QUANT with
    // '-' peak_rss/rchar whose series comes from its monitoring CSV
    let filter_ms = &trace.runs_of("FILTER")[3];
    assert!((filter_ms.runtime.0 - 0.75).abs() < 1e-9, "750ms realtime");
    let quant_dash = &trace.runs_of("QUANT")[4];
    assert!((quant_dash.runtime.0 - 12.5).abs() < 1e-9, "12.5s realtime");
    assert_eq!(quant_dash.series.len(), 3, "series from samples/16.csv");
    assert_eq!(quant_dash.peak(), MemMiB::parse("1.44 GB").unwrap());
    assert_eq!(quant_dash.input_mib, 0.0, "'-' rchar defaults to 0");

    // ALIGN has real monitoring series (5 ramp samples at 2 s)
    let align0 = &trace.runs_of("ALIGN")[0];
    assert_eq!(align0.series.len(), 5);
    assert_eq!(align0.series.interval().0, 2.0);
    assert_eq!(align0.peak(), MemMiB::parse("400 MB").unwrap());
    assert_eq!(align0.runtime, Seconds(10.0));
    assert_eq!(align0.input_mib, MemMiB::parse("100 MB").unwrap().0);
    // FILTER has no sample CSVs: flat fallback series at peak_rss
    let filter0 = &trace.runs_of("FILTER")[0];
    assert_eq!(filter0.series.len(), 1);
    assert_eq!(filter0.peak(), MemMiB::parse("256 MB").unwrap());
    assert_eq!(filter0.series.duration(), Seconds(5.0));
}

/// The satellite round-trip property on the fixture:
/// ingest(NextflowDir) → write_trace_jsonl → read_trace_jsonl is the
/// identity (both writers).
#[test]
fn nextflow_ingest_jsonl_roundtrip() {
    let trace = read_nextflow_dir(&fixture_dir()).unwrap();
    let grouped = tmp("fixture_grouped.jsonl");
    write_trace_jsonl(&trace, &grouped).unwrap();
    assert_eq!(read_trace_jsonl(&grouped).unwrap(), trace);
    let ordered = tmp("fixture_ordered.jsonl");
    write_trace_jsonl_ordered(&trace, &ordered).unwrap();
    assert_eq!(read_trace_jsonl(&ordered).unwrap(), trace);
}

/// The same property over randomized traces (deterministic rng).
#[test]
fn randomized_jsonl_roundtrip_property() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed);
        let mut trace = Trace::new();
        let n_types = 1 + (rng.f64() * 4.0) as usize;
        for k in 0..n_types {
            let ty = format!("wf/t{k}");
            if rng.f64() < 0.7 {
                trace.set_default(&ty, MemMiB(rng.uniform(100.0, 9000.0)));
            }
        }
        // round-robin types so every type with a default also has runs
        // (the jsonl writers only emit defaults of types that ran)
        let n_runs = 5 + (rng.f64() * 20.0) as usize;
        for seq in 0..n_runs {
            let ty = format!("wf/t{}", seq % n_types);
            let n_samples = 1 + (rng.f64() * 12.0) as usize;
            let samples: Vec<f64> = (0..n_samples).map(|_| rng.uniform(0.0, 4000.0)).collect();
            trace.push(TaskRun {
                task_type: ty,
                input_mib: rng.uniform(0.0, 5000.0),
                runtime: Seconds(rng.uniform(0.1, 500.0)),
                series: UsageSeries::new(rng.uniform(0.5, 5.0), samples),
                seq: seq as u64,
            });
        }
        trace.sort();
        let path = tmp(&format!("random_{seed}.jsonl"));
        write_trace_jsonl_ordered(&trace, &path).unwrap();
        assert_eq!(read_trace_jsonl(&path).unwrap(), trace, "seed {seed}");
    }
}

/// Acceptance criterion: `ksegments replay` over the fixture is
/// bit-identical at workers = 1 vs 8 — and across all three source
/// kinds (NextflowDir, streaming JsonlReader of the ingested file,
/// InMemory).
#[test]
fn replay_fixture_bit_identical_across_workers_and_sources() {
    let cfg = ReplayConfig { chunk: 3, ..ReplayConfig::default() };
    let mut dir_src = NextflowDirSource::open(&fixture_dir()).unwrap();
    let base = replay_source(&mut dir_src, &kseg_factory, &cfg, 1, None).unwrap();
    assert_eq!(base.runs_replayed, 14);
    assert_eq!(base.runs_warmup, 6, "2-run warm-up per type x 3 types");
    assert_eq!(base.report.tasks.len(), 3);
    assert!(base.report.tasks.iter().all(|t| t.n_scored > 0));

    for workers in [2, 8] {
        dir_src.rewind().unwrap();
        let out = replay_source(&mut dir_src, &kseg_factory, &cfg, workers, None).unwrap();
        assert_eq!(out, base, "workers={workers} diverged");
    }

    // the ingested jsonl file streams to the same outcome...
    let trace = read_nextflow_dir(&fixture_dir()).unwrap();
    let path = tmp("replay_fixture.jsonl");
    write_trace_jsonl_ordered(&trace, &path).unwrap();
    let mut jsonl_src = JsonlReader::open(&path).unwrap();
    let via_jsonl = replay_source(&mut jsonl_src, &kseg_factory, &cfg, 8, None).unwrap();
    assert_eq!(via_jsonl, base);
    // ...and so does the in-memory adapter
    let mut mem_src = InMemorySource::from_trace(&trace);
    let via_mem = replay_source(&mut mem_src, &kseg_factory, &cfg, 4, None).unwrap();
    assert_eq!(via_mem, base);
}

/// Acceptance criterion: a warm-start replay from a checkpoint ends in
/// the same predictor state as one uninterrupted cold replay — both as
/// a value and byte-for-byte on disk.
#[test]
fn warm_start_checkpoint_matches_cold_replay() {
    let cfg = ReplayConfig::default();
    let trace = read_nextflow_dir(&fixture_dir()).unwrap();

    let mut cold_src = InMemorySource::from_trace(&trace);
    let cold = replay_source(&mut cold_src, &kseg_factory, &cfg, 4, None).unwrap();

    let defaults = InMemorySource::from_trace(&trace).defaults();
    let all: Vec<TaskRun> = trace.all_runs_ordered().into_iter().cloned().collect();
    let (first_half, second_half) = all.split_at(all.len() / 2);
    let mut src_a = InMemorySource::from_runs(defaults.clone(), first_half.to_vec());
    let session_a = replay_source(&mut src_a, &kseg_factory, &cfg, 2, None).unwrap();
    let mut src_b = InMemorySource::from_runs(defaults, second_half.to_vec());
    let session_b = replay_source(&mut src_b, &kseg_factory, &cfg, 8, Some(&session_a.checkpoint))
        .unwrap();

    assert_eq!(session_b.checkpoint, cold.checkpoint);
    // serialized state is byte-identical (deterministic layout)
    let p_cold = tmp("cold.ckpt.jsonl");
    let p_warm = tmp("warm.ckpt.jsonl");
    cold.checkpoint.save(&p_cold).unwrap();
    session_b.checkpoint.save(&p_warm).unwrap();
    assert_eq!(std::fs::read(&p_cold).unwrap(), std::fs::read(&p_warm).unwrap());
    // and the save/load round trip preserves it exactly
    assert_eq!(Checkpoint::load(&p_warm).unwrap(), cold.checkpoint);
    // both paths saw every run
    assert_eq!(session_a.runs_replayed + session_b.runs_replayed, cold.runs_replayed);
}

/// The scheduler consumes the same stream either way: materialized
/// `schedule_trace` at `training_frac = 0` vs `schedule_stream` over
/// the streaming JSONL reader.
#[test]
fn fixture_schedules_identically_from_stream_and_trace() {
    let trace = read_nextflow_dir(&fixture_dir()).unwrap();
    let cfg = SchedConfig { training_frac: 0.0, ..SchedConfig::default() };
    let mut p1 = PpmPredictor::improved();
    let materialized = schedule_trace(&trace, &mut p1, &cfg);
    assert_eq!(materialized.completed, 14);

    let path = tmp("sched_fixture.jsonl");
    write_trace_jsonl_ordered(&trace, &path).unwrap();
    let mut src = JsonlReader::open(&path).unwrap();
    let mut p2 = PpmPredictor::improved();
    let (streamed, _log) = schedule_stream(&mut src, &mut p2, &cfg, 4).unwrap();
    assert_eq!(streamed, materialized);
}
