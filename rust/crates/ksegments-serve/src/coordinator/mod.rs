//! The prediction service — the long-running coordinator a SWMS talks
//! to (the deployment shape of Fig. 2/6), sharded for throughput.
//!
//! N model threads (shards) each own a private predictor (and through
//! it the PJRT runtime, which wants single-threaded use). Task types
//! are hash-partitioned across shards, so all traffic for one type
//! flows through one shard's FIFO channel — which preserves the online
//! contract: completions a client sends before a predict are ingested
//! before that predict is answered. SWMS-side clients hold a cheap
//! clonable [`ServiceHandle`] and talk to the shards over channels:
//!
//! * [`ServiceHandle::predict`] — blocking request/response, the
//!   submission-time path;
//! * [`ServiceHandle::report_failure`] — blocking, returns the retry
//!   allocation per the predictor's failure strategy;
//! * [`ServiceHandle::complete`] — fire-and-forget completion
//!   ingestion; each shard drains all queued requests per wakeup, so a
//!   burst of completions is folded into the model as one batch before
//!   the thread sleeps again.
//!
//! [`PredictionService`] (the original single-model deployment) is the
//! `shards = 1` case of the same code path. The offline crate cache
//! has no tokio; the service uses std threads and mpsc channels, which
//! for this request pattern (model owner per shard, many blocking
//! callers) is the same architecture tokio's actor pattern would
//! express.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use ksegments_core::predictors::{Allocation, FailureInfo, MemoryPredictor};
use ksegments_core::telemetry::{ArgValue, Registry, TraceEvent};
use ksegments_core::trace::TaskRun;
use ksegments_core::units::MemMiB;
use ksegments_core::util::timer::Stopwatch;

/// Requests understood by a shard's model thread.
enum Request {
    Prime { task_type: String, default: MemMiB },
    Predict { task_type: String, input_mib: f64, reply: Sender<Allocation> },
    Failure {
        task_type: String,
        input_mib: f64,
        failed: Allocation,
        info: FailureInfo,
        reply: Sender<Allocation>,
    },
    Complete { run: Box<TaskRun> },
    /// Checkpoint warm-start: feed a historical run into the model
    /// without counting it as new traffic (the completion counter is
    /// untouched, so stats after a warm restart reflect only what the
    /// restarted service actually served).
    Restore { run: Box<TaskRun> },
    Stats { reply: Sender<ServiceStats> },
    Shutdown,
}

/// Observability counters maintained per shard; aggregate across
/// shards with [`ServiceStats::merge`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub predictions: u64,
    pub completions: u64,
    pub failures: u64,
    /// Model-thread wakeups: batched draining means this can be far
    /// below the total request count under bursty traffic.
    pub wakeups: u64,
}

impl ServiceStats {
    /// Add another shard's counters into this one.
    pub fn merge(&mut self, other: ServiceStats) {
        self.predictions += other.predictions;
        self.completions += other.completions;
        self.failures += other.failures;
        self.wakeups += other.wakeups;
    }

    /// Sum of per-shard stats.
    pub fn aggregated(per_shard: &[ServiceStats]) -> ServiceStats {
        let mut total = ServiceStats::default();
        for s in per_shard {
            total.merge(*s);
        }
        total
    }
}

/// Export per-shard counters (labelled `shard="N"`) plus the
/// aggregate into a metrics registry.
pub fn export_service_metrics(per_shard: &[ServiceStats], reg: &mut Registry) {
    for (s, st) in per_shard.iter().enumerate() {
        reg.counter_add(&format!("service_predictions{{shard=\"{s}\"}}"), st.predictions);
        reg.counter_add(&format!("service_completions{{shard=\"{s}\"}}"), st.completions);
        reg.counter_add(&format!("service_failures{{shard=\"{s}\"}}"), st.failures);
        reg.counter_add(&format!("service_wakeups{{shard=\"{s}\"}}"), st.wakeups);
    }
    let total = ServiceStats::aggregated(per_shard);
    reg.counter_add("service_predictions_total", total.predictions);
    reg.counter_add("service_completions_total", total.completions);
    reg.counter_add("service_failures_total", total.failures);
    reg.counter_add("service_wakeups_total", total.wakeups);
    reg.gauge_set("service_shards", per_shard.len() as f64);
}

/// FNV-1a partition of task types over shards — the same type always
/// lands on the same shard, which is what carries the per-type FIFO
/// guarantee. Public because the streaming replay engine
/// ([`crate::ingest::replay`]) shards its workers with the same
/// function, so a replayed type lands on the same shard index it would
/// occupy in the live service.
pub fn shard_of(task_type: &str, n_shards: usize) -> usize {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in task_type.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h % n_shards as u64) as usize
}

/// Clonable client handle; routes every request to the owning shard.
#[derive(Clone)]
pub struct ServiceHandle {
    txs: Vec<Sender<Request>>,
}

impl ServiceHandle {
    fn tx_for(&self, task_type: &str) -> &Sender<Request> {
        &self.txs[shard_of(task_type, self.txs.len())]
    }

    pub fn prime(&self, task_type: &str, default: MemMiB) {
        let _ = self.tx_for(task_type).send(Request::Prime {
            task_type: task_type.to_string(),
            default,
        });
    }

    /// Submission-time allocation request (blocking). Panics if the
    /// service is down; see [`ServiceHandle::try_predict`] for the
    /// non-panicking variant.
    pub fn predict(&self, task_type: &str, input_mib: f64) -> Allocation {
        self.try_predict(task_type, input_mib)
            .expect("prediction service is down")
    }

    /// Submission-time allocation request; `None` once the service has
    /// shut down (callers racing a shutdown fall back to defaults).
    pub fn try_predict(&self, task_type: &str, input_mib: f64) -> Option<Allocation> {
        let (reply, rx) = channel();
        self.tx_for(task_type)
            .send(Request::Predict { task_type: task_type.to_string(), input_mib, reply })
            .ok()?;
        rx.recv().ok()
    }

    /// Failure-strategy request (blocking). Panics if the service is
    /// down; see [`ServiceHandle::try_report_failure`].
    pub fn report_failure(
        &self,
        task_type: &str,
        input_mib: f64,
        failed: Allocation,
        info: FailureInfo,
    ) -> Allocation {
        self.try_report_failure(task_type, input_mib, failed, info)
            .expect("prediction service is down")
    }

    /// Failure-strategy request; `None` once the service has shut down.
    pub fn try_report_failure(
        &self,
        task_type: &str,
        input_mib: f64,
        failed: Allocation,
        info: FailureInfo,
    ) -> Option<Allocation> {
        let (reply, rx) = channel();
        self.tx_for(task_type)
            .send(Request::Failure {
                task_type: task_type.to_string(),
                input_mib,
                failed,
                info,
                reply,
            })
            .ok()?;
        rx.recv().ok()
    }

    /// Completion ingestion (non-blocking; silently dropped after
    /// shutdown).
    pub fn complete(&self, run: TaskRun) {
        let _ = self.tx_for(&run.task_type).send(Request::Complete { run: Box::new(run) });
    }

    /// Stream a [`TraceSource`] through the service: prime its
    /// defaults, then predict + complete every run in arrival order,
    /// chunk by chunk — the service-side replay path, which never
    /// materializes the trace. Returns the number of runs fed; errors
    /// if the source fails or the service is already down.
    ///
    /// [`TraceSource`]: crate::ingest::TraceSource
    pub fn replay_source(
        &self,
        src: &mut dyn crate::ingest::TraceSource,
        chunk: usize,
    ) -> anyhow::Result<u64> {
        for (ty, mem) in src.defaults() {
            self.prime(&ty, mem);
        }
        let mut fed = 0u64;
        loop {
            let batch = src.next_chunk(chunk.max(1))?;
            if batch.is_empty() {
                return Ok(fed);
            }
            for run in batch {
                if self.try_predict(&run.task_type, run.input_mib).is_none() {
                    anyhow::bail!("prediction service shut down mid-replay");
                }
                self.complete(run);
                fed += 1;
            }
        }
    }

    /// Warm-start every shard from a saved predictor checkpoint: prime
    /// each recorded default, then feed each windowed run (oldest
    /// first) through the owning shard's `observe` — the channel-level
    /// mirror of [`Checkpoint::restore_into`]. Restored history never
    /// bumps the service counters, so stats after a warm restart count
    /// only new traffic; per-type FIFO routing guarantees any request
    /// sent afterwards observes the fully restored state.
    ///
    /// [`Checkpoint::restore_into`]: crate::ingest::Checkpoint::restore_into
    pub fn restore_checkpoint(&self, ck: &crate::ingest::Checkpoint) {
        for (ty, st) in ck.types() {
            if let Some(d) = st.default_mib {
                self.prime(ty, MemMiB(d));
            }
            for run in &st.runs {
                let _ = self.tx_for(ty).send(Request::Restore { run: Box::new(run.clone()) });
            }
        }
    }

    /// Aggregated counters across all shards (blocking) — the live
    /// snapshot-while-running path: the model threads keep serving,
    /// and per-shard FIFO ordering makes each shard's answer exact as
    /// of every request that shard had ingested when it replied.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats::aggregated(&self.per_shard_stats())
    }

    /// Per-shard counters (blocking; a shard that already shut down
    /// reports zeros). See [`ServiceHandle::try_per_shard_stats`] for
    /// the variant that reports a partial roster as unavailable.
    pub fn per_shard_stats(&self) -> Vec<ServiceStats> {
        self.txs
            .iter()
            .map(|tx| {
                let (reply, rx) = channel();
                if tx.send(Request::Stats { reply }).is_err() {
                    return ServiceStats::default();
                }
                rx.recv().unwrap_or_default()
            })
            .collect()
    }

    /// Live per-shard snapshot; `None` once any shard has shut down —
    /// unlike [`ServiceHandle::per_shard_stats`], a dead shard makes
    /// the whole snapshot unavailable instead of being silently
    /// reported as zeros (what the network `stats` frame relies on to
    /// never under-report totals).
    pub fn try_per_shard_stats(&self) -> Option<Vec<ServiceStats>> {
        self.txs
            .iter()
            .map(|tx| {
                let (reply, rx) = channel();
                tx.send(Request::Stats { reply }).ok()?;
                rx.recv().ok()
            })
            .collect()
    }
}

/// The running sharded service; join it via
/// [`ShardedPredictionService::shutdown`] or let `Drop` do it.
pub struct ShardedPredictionService {
    handle: ServiceHandle,
    threads: Vec<JoinHandle<(ServiceStats, Vec<TraceEvent>)>>,
}

impl ShardedPredictionService {
    /// Spawn `n_shards` model threads, each owning the predictor the
    /// factory builds for its shard index.
    pub fn spawn(
        n_shards: usize,
        factory: impl Fn(usize) -> Box<dyn MemoryPredictor>,
    ) -> ShardedPredictionService {
        Self::spawn_opts((0..n_shards).map(&factory).collect(), false)
    }

    /// [`ShardedPredictionService::spawn`] with per-wakeup trace spans
    /// collected on every shard; retrieve them with
    /// [`ShardedPredictionService::shutdown_with_trace`]. Service
    /// spans are **wall-clock**-stamped (the one sanctioned use of
    /// wall time in a trace — DESIGN.md §12) and observation-only:
    /// predictions and counters are unchanged.
    pub fn spawn_traced(
        n_shards: usize,
        factory: impl Fn(usize) -> Box<dyn MemoryPredictor>,
    ) -> ShardedPredictionService {
        Self::spawn_opts((0..n_shards).map(&factory).collect(), true)
    }

    /// Spawn one shard per provided predictor (at least one).
    pub fn spawn_with(predictors: Vec<Box<dyn MemoryPredictor>>) -> ShardedPredictionService {
        Self::spawn_opts(predictors, false)
    }

    fn spawn_opts(
        predictors: Vec<Box<dyn MemoryPredictor>>,
        traced: bool,
    ) -> ShardedPredictionService {
        assert!(!predictors.is_empty(), "service needs at least one shard");
        let epoch = Stopwatch::start();
        let mut txs = Vec::with_capacity(predictors.len());
        let mut threads = Vec::with_capacity(predictors.len());
        for (s, predictor) in predictors.into_iter().enumerate() {
            let (tx, rx) = channel();
            let trace = traced.then_some((epoch, s as u32));
            let thread = std::thread::Builder::new()
                .name(format!("ksegments-shard-{s}"))
                .spawn(move || model_loop(predictor, rx, trace))
                .expect("spawning shard model thread");
            txs.push(tx);
            threads.push(thread);
        }
        ShardedPredictionService { handle: ServiceHandle { txs }, threads }
    }

    pub fn n_shards(&self) -> usize {
        self.handle.txs.len()
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Live aggregated counters without stopping the service — the
    /// snapshot-while-running path (the network `stats` frame and any
    /// in-process observer poll this while traffic is flowing).
    pub fn stats(&self) -> ServiceStats {
        self.handle.stats()
    }

    /// Stop all shards and return their aggregated final counters.
    pub fn shutdown(mut self) -> ServiceStats {
        ServiceStats::aggregated(&self.shutdown_stats())
    }

    /// Stop all shards and return the per-shard final counters, in
    /// shard order.
    pub fn shutdown_per_shard(mut self) -> Vec<ServiceStats> {
        self.shutdown_stats()
    }

    /// Stop all shards, returning per-shard counters plus the merged
    /// wakeup trace (empty unless spawned via
    /// [`ShardedPredictionService::spawn_traced`]), sorted by
    /// timestamp then shard track.
    pub fn shutdown_with_trace(mut self) -> (Vec<ServiceStats>, Vec<TraceEvent>) {
        let mut stats = Vec::with_capacity(self.threads.len());
        let mut trace = Vec::new();
        for (s, t) in self.join_shards() {
            stats.push(s);
            trace.extend(t);
        }
        trace.sort_by_key(|e| (e.ts_us, e.tid));
        (stats, trace)
    }

    fn shutdown_stats(&mut self) -> Vec<ServiceStats> {
        self.join_shards().into_iter().map(|(s, _)| s).collect()
    }

    fn join_shards(&mut self) -> Vec<(ServiceStats, Vec<TraceEvent>)> {
        for tx in &self.handle.txs {
            let _ = tx.send(Request::Shutdown);
        }
        self.threads
            .drain(..)
            .map(|t| t.join().expect("shard model thread panicked"))
            .collect()
    }
}

impl Drop for ShardedPredictionService {
    fn drop(&mut self) {
        if !self.threads.is_empty() {
            for tx in &self.handle.txs {
                let _ = tx.send(Request::Shutdown);
            }
            for t in self.threads.drain(..) {
                let _ = t.join();
            }
        }
    }
}

/// The single-model deployment — exactly the sharded service with one
/// shard (same model loop, same handle type).
pub struct PredictionService {
    inner: ShardedPredictionService,
}

impl PredictionService {
    /// Spawn the model thread around any predictor.
    pub fn spawn(predictor: Box<dyn MemoryPredictor>) -> PredictionService {
        PredictionService { inner: ShardedPredictionService::spawn_with(vec![predictor]) }
    }

    pub fn handle(&self) -> ServiceHandle {
        self.inner.handle()
    }

    /// Stop the model thread and return its final counters.
    pub fn shutdown(self) -> ServiceStats {
        self.inner.shutdown()
    }
}

/// One shard's model loop: block on the first request of a wakeup,
/// then drain everything already queued and process the batch in
/// arrival order (so completion bursts cost one wakeup, and ordering
/// guarantees are untouched). With `trace` set, every wakeup is
/// recorded as a wall-clock async span on the shard's track.
fn model_loop(
    mut predictor: Box<dyn MemoryPredictor>,
    rx: Receiver<Request>,
    trace: Option<(Stopwatch, u32)>,
) -> (ServiceStats, Vec<TraceEvent>) {
    let mut stats = ServiceStats::default();
    let mut events: Vec<TraceEvent> = Vec::new();
    let mut batch = Vec::new();
    'serve: while let Ok(first) = rx.recv() {
        stats.wakeups += 1;
        let begin_us = trace.map(|(epoch, _)| epoch.elapsed_us());
        batch.clear();
        batch.push(first);
        while let Ok(more) = rx.try_recv() {
            batch.push(more);
        }
        let n_batch = batch.len() as u64;
        for req in batch.drain(..) {
            match req {
                Request::Prime { task_type, default } => predictor.prime(&task_type, default),
                Request::Predict { task_type, input_mib, reply } => {
                    stats.predictions += 1;
                    let _ = reply.send(predictor.predict(&task_type, input_mib));
                }
                Request::Failure { task_type, input_mib, failed, info, reply } => {
                    stats.failures += 1;
                    let _ = reply.send(predictor.on_failure(&task_type, input_mib, &failed, &info));
                }
                Request::Complete { run } => {
                    stats.completions += 1;
                    predictor.observe(&run);
                }
                Request::Restore { run } => predictor.observe(&run),
                Request::Stats { reply } => {
                    let _ = reply.send(stats);
                }
                Request::Shutdown => break 'serve,
            }
        }
        if let (Some((epoch, shard)), Some(ts_b)) = (trace, begin_us) {
            let id = ((u64::from(shard) << 32) | (stats.wakeups - 1)) & 0xffff_ffff_ffff;
            let ts_e = epoch.elapsed_us().max(ts_b);
            for (ph, ts) in [('b', ts_b), ('e', ts_e)] {
                events.push(TraceEvent {
                    name: "wakeup".to_string(),
                    cat: "service",
                    ph,
                    ts_us: ts,
                    pid: 0,
                    tid: shard,
                    id: Some(id),
                    args: if ph == 'b' {
                        vec![("batch", ArgValue::U64(n_batch))]
                    } else {
                        Vec::new()
                    },
                });
            }
        }
    }
    (stats, events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::predictors::default_config::DefaultConfigPredictor;
    use ksegments_core::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
    use ksegments_core::trace::UsageSeries;
    use ksegments_core::units::Seconds;

    fn run_of(ty: &str, input: f64, peak: f64) -> TaskRun {
        let samples: Vec<f64> = (0..8).map(|j| peak * (j + 1) as f64 / 8.0).collect();
        TaskRun {
            task_type: ty.into(),
            input_mib: input,
            runtime: Seconds(16.0),
            series: UsageSeries::new(2.0, samples),
            seq: 0,
        }
    }

    fn run(input: f64, peak: f64) -> TaskRun {
        run_of("w/t", input, peak)
    }

    #[test]
    fn predict_roundtrip() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        h.prime("w/t", MemMiB(2048.0));
        assert_eq!(h.predict("w/t", 10.0), Allocation::Static(MemMiB(2048.0)));
        let stats = svc.shutdown();
        assert_eq!(stats.predictions, 1);
    }

    #[test]
    fn completions_train_the_model() {
        let svc = PredictionService::spawn(Box::new(KSegmentsPredictor::native(
            4,
            RetryStrategy::Selective,
        )));
        let h = svc.handle();
        h.prime("w/t", MemMiB(2048.0));
        for i in 0..12 {
            h.complete(run(100.0 + 10.0 * i as f64, 200.0 + 10.0 * i as f64));
        }
        // channel is FIFO: by the time predict is answered, all
        // completions have been ingested
        let alloc = h.predict("w/t", 150.0);
        assert!(alloc.is_dynamic());
        let stats = svc.shutdown();
        assert_eq!(stats.completions, 12);
    }

    #[test]
    fn failure_path_returns_escalated_allocation() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        let failed = Allocation::Static(MemMiB(100.0));
        let info = FailureInfo::oom(1.0, 150.0, 1);
        let next = h.report_failure("w/t", 10.0, failed, info);
        assert_eq!(next, Allocation::Static(MemMiB(200.0)));
        assert_eq!(svc.shutdown().failures, 1);
    }

    #[test]
    fn many_clients_share_the_service() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let mut joins = Vec::new();
        for i in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let _ = h.predict(&format!("w/t{i}"), 1.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(svc.shutdown().predictions, 400);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        drop(svc);
        // handle calls after shutdown must not panic the caller thread
        // (send fails silently for fire-and-forget)
        h.complete(run(1.0, 1.0));
        assert!(h.try_predict("w/t", 1.0).is_none());
    }

    #[test]
    fn shard_routing_is_stable_and_total() {
        for n in 1..8 {
            for ty in ["a", "b/c", "eager/qualimap", "sarek/bwamem", ""] {
                let s = shard_of(ty, n);
                assert!(s < n);
                assert_eq!(s, shard_of(ty, n), "routing must be deterministic");
            }
        }
    }

    #[test]
    fn sharded_service_partitions_types_and_aggregates_stats() {
        let svc = ShardedPredictionService::spawn(4, |_| Box::new(DefaultConfigPredictor::new()));
        assert_eq!(svc.n_shards(), 4);
        let h = svc.handle();
        for i in 0..32 {
            let ty = format!("w/t{i}");
            h.prime(&ty, MemMiB(512.0));
            assert_eq!(h.predict(&ty, 1.0), Allocation::Static(MemMiB(512.0)));
            h.complete(run_of(&ty, 1.0, 100.0));
        }
        let per_shard = svc.shutdown_per_shard();
        assert_eq!(per_shard.len(), 4);
        let total = ServiceStats::aggregated(&per_shard);
        assert_eq!(total.predictions, 32);
        assert_eq!(total.completions, 32);
        // with 32 hashed types over 4 shards, no shard should be idle
        assert!(per_shard.iter().all(|s| s.predictions > 0), "{per_shard:?}");
    }

    #[test]
    fn sharded_completions_before_predict_per_type() {
        // FIFO per task type must hold with multiple shards: the
        // completions routed to a type's shard are ingested before the
        // predict sent afterwards by the same client.
        let svc = ShardedPredictionService::spawn(3, |_| {
            Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
        });
        let h = svc.handle();
        for ty in ["w/a", "w/b", "w/c", "w/d"] {
            h.prime(ty, MemMiB(2048.0));
            for i in 0..12 {
                h.complete(run_of(ty, 100.0 + 10.0 * i as f64, 200.0 + 10.0 * i as f64));
            }
            assert!(h.predict(ty, 150.0).is_dynamic(), "{ty} predict ran before completions");
        }
        assert_eq!(svc.shutdown().completions, 48);
    }

    #[test]
    fn replay_source_streams_defaults_and_runs() {
        let mut trace = ksegments_core::trace::Trace::new();
        trace.set_default("w/t", MemMiB(2048.0));
        for i in 0..12u64 {
            let mut r = run(100.0 + 10.0 * i as f64, 200.0 + 10.0 * i as f64);
            r.seq = i;
            trace.push(r);
        }
        trace.sort();
        let mut src = crate::ingest::InMemorySource::from_trace(&trace);
        let svc = ShardedPredictionService::spawn(2, |_| {
            Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
        });
        let h = svc.handle();
        let fed = h.replay_source(&mut src, 5).unwrap();
        assert_eq!(fed, 12);
        // all completions ingested before this predict (per-type FIFO)
        assert!(h.predict("w/t", 150.0).is_dynamic());
        let stats = svc.shutdown();
        assert_eq!(stats.completions, 12);
        assert_eq!(stats.predictions, 13);
    }

    #[test]
    fn traced_service_records_wakeup_spans() {
        let svc =
            ShardedPredictionService::spawn_traced(2, |_| Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        h.prime("w/a", MemMiB(512.0));
        for _ in 0..5 {
            let _ = h.predict("w/a", 1.0);
        }
        let (stats, trace) = svc.shutdown_with_trace();
        assert_eq!(ServiceStats::aggregated(&stats).predictions, 5);
        assert!(!trace.is_empty());
        let begins = trace.iter().filter(|e| e.ph == 'b').count();
        let ends = trace.iter().filter(|e| e.ph == 'e').count();
        assert_eq!(begins, ends, "every wakeup span must close");
        assert!(trace.iter().all(|e| e.cat == "service"));
        assert!(trace.windows(2).all(|w| w[0].ts_us <= w[1].ts_us), "merged trace sorted");
    }

    #[test]
    fn untraced_service_collects_no_trace() {
        let svc = ShardedPredictionService::spawn(2, |_| Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        h.prime("w/a", MemMiB(512.0));
        let _ = h.predict("w/a", 1.0);
        let (stats, trace) = svc.shutdown_with_trace();
        assert!(trace.is_empty());
        assert_eq!(ServiceStats::aggregated(&stats).predictions, 1);
    }

    #[test]
    fn service_metrics_export_labels_shards() {
        let per_shard = vec![
            ServiceStats { predictions: 3, completions: 2, failures: 1, wakeups: 4 },
            ServiceStats { predictions: 5, completions: 0, failures: 0, wakeups: 2 },
        ];
        let mut reg = ksegments_core::telemetry::Registry::new();
        export_service_metrics(&per_shard, &mut reg);
        assert_eq!(reg.counter("service_predictions{shard=\"0\"}"), 3);
        assert_eq!(reg.counter("service_predictions{shard=\"1\"}"), 5);
        assert_eq!(reg.counter("service_predictions_total"), 8);
        assert_eq!(reg.counter("service_wakeups_total"), 6);
        assert_eq!(reg.gauge("service_shards"), Some(2.0));
        let prom = reg.to_prometheus();
        assert!(prom.contains("service_predictions{shard=\"0\"} 3"), "{prom}");
    }

    #[test]
    fn batched_draining_counts_fewer_wakeups_than_requests() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        for i in 0..200 {
            h.complete(run(i as f64, 100.0));
        }
        let stats = svc.shutdown();
        assert_eq!(stats.completions, 200);
        // batching can never take MORE wakeups than messages (+1 for
        // the shutdown); under any real schedule it takes far fewer
        assert!(stats.wakeups <= stats.completions + 1, "{stats:?}");
    }

    #[test]
    fn live_stats_snapshot_while_running() {
        let svc = ShardedPredictionService::spawn(2, |_| Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        h.prime("w/a", MemMiB(512.0));
        for _ in 0..5 {
            let _ = h.predict("w/a", 1.0);
        }
        // snapshot without stopping: the per-shard FIFO means every
        // predict answered so far is already counted
        let live = svc.stats();
        assert_eq!(live.predictions, 5);
        let per_shard = h.try_per_shard_stats().expect("all shards up");
        assert_eq!(per_shard.len(), 2);
        assert_eq!(ServiceStats::aggregated(&per_shard).predictions, 5);
        // the service keeps serving after a snapshot
        let _ = h.predict("w/a", 1.0);
        assert_eq!(svc.shutdown().predictions, 6);
    }

    #[test]
    fn try_per_shard_stats_unavailable_after_shutdown() {
        let svc = ShardedPredictionService::spawn(2, |_| Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        drop(svc);
        // the lossy variant silently zeroes dead shards ...
        assert_eq!(ServiceStats::aggregated(&h.per_shard_stats()), ServiceStats::default());
        // ... the strict one refuses to under-report
        assert!(h.try_per_shard_stats().is_none());
    }

    #[test]
    fn restore_checkpoint_reproduces_observed_state() {
        use crate::ingest::Checkpoint;

        // train one service directly ...
        let direct = PredictionService::spawn(Box::new(KSegmentsPredictor::native(
            4,
            RetryStrategy::Selective,
        )));
        let hd = direct.handle();
        hd.prime("w/t", MemMiB(2048.0));
        let mut ck = Checkpoint::new(Checkpoint::DEFAULT_WINDOW);
        ck.record_default("w/t", MemMiB(2048.0));
        for i in 0..12 {
            let r = run(100.0 + 10.0 * i as f64, 200.0 + 10.0 * i as f64);
            ck.record(&r);
            hd.complete(r);
        }
        let direct_alloc = hd.predict("w/t", 150.0);

        // ... and warm-start a fresh one from the checkpoint alone
        let warm = PredictionService::spawn(Box::new(KSegmentsPredictor::native(
            4,
            RetryStrategy::Selective,
        )));
        let hw = warm.handle();
        hw.restore_checkpoint(&ck);
        assert_eq!(hw.predict("w/t", 150.0), direct_alloc);
        // restored history is not new traffic: only the probe counts
        let stats = warm.shutdown();
        assert_eq!(stats.completions, 0);
        assert_eq!(stats.predictions, 1);
    }
}
