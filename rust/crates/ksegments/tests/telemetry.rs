//! Telemetry lockdown: observation-only tracing (bit-identical
//! reports and event logs with every sink attached), worker-count
//! independent replay traces, permutation-invariant metrics merges,
//! Chrome-trace validity of a failure-heavy DAG run (the `--trace-out`
//! acceptance criterion), and provenance JSONL round-trips.

use ksegments::ingest::{replay_source, InMemorySource, ReplayConfig};
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::MemoryPredictor;
use ksegments::sched::{
    schedule_trace_logged, schedule_trace_telemetry, schedule_workflows_telemetry, SchedConfig,
    WorkflowSource,
};
use ksegments::telemetry::{ChromeTraceSink, ProvenanceLog, Registry, RunTelemetry};
use ksegments::units::Seconds;
use ksegments::util::json::Json;
use ksegments::workload::{eager_workflow, generate_workflow_trace, sarek_workflow};

/// Unique-per-test temp path (tests in this binary run in parallel).
fn temp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("ksegments_{}_{name}", std::process::id()))
}

/// A scheduling config that exercises the adversity machinery —
/// node failures and preemption on top of OOM retries — so traced
/// runs cover every kill path.
fn adversity_cfg(seed: u64) -> SchedConfig {
    SchedConfig {
        seed,
        training_frac: 0.4,
        fail_mtbf: Seconds(900.0),
        preempt: true,
        ..SchedConfig::default()
    }
}

/// THE golden rule: attaching a trace sink and a provenance log must
/// leave the report and the engine event log bit-identical to the
/// untraced run — telemetry observes, never influences.
#[test]
fn traced_run_is_bit_identical_to_untraced() {
    let trace = generate_workflow_trace(&eager_workflow(), 42);
    let cfg = adversity_cfg(42);

    let mut plain_p = KSegmentsPredictor::native(4, RetryStrategy::Selective);
    let (plain_rep, plain_log) = schedule_trace_logged(&trace, &mut plain_p, &cfg);

    let path = temp_path("bitident_trace.json");
    let sink = ChromeTraceSink::create(path.to_str().unwrap()).unwrap();
    let mut tel = RunTelemetry::with_trace(Box::new(sink));
    tel.provenance = Some(ProvenanceLog::to_writer(Box::new(std::io::sink())));
    let mut traced_p = KSegmentsPredictor::native(4, RetryStrategy::Selective);
    let (traced_rep, traced_log) = schedule_trace_telemetry(&trace, &mut traced_p, &cfg, &mut tel);
    let prov_records = tel.provenance.as_ref().map_or(0, ProvenanceLog::len);
    tel.finish().unwrap();

    assert_eq!(plain_rep, traced_rep, "telemetry must never perturb the report");
    assert_eq!(plain_log.len(), traced_log.len());
    assert!(plain_log.iter().eq(traced_log.iter()), "telemetry must never perturb the event log");

    // ... and the attachments really observed the run (not vacuous).
    let doc = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let n_events = Json::parse(&doc).unwrap().get("traceEvents").as_arr().unwrap().len();
    assert!(n_events > 0, "trace sink saw no events");
    assert!(prov_records > 0, "provenance log saw no decisions");
}

/// Replay trace events are `run.seq`-stamped and merged
/// deterministically, so the whole outcome — trace included — is
/// identical at any worker count.
#[test]
fn replay_trace_is_worker_count_independent() {
    let trace = generate_workflow_trace(&eager_workflow(), 42);
    let make = || -> Box<dyn MemoryPredictor> {
        Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
    };
    let cfg = ReplayConfig { collect_trace: true, ..ReplayConfig::default() };

    let mut src1 = InMemorySource::from_trace(&trace);
    let one = replay_source(&mut src1, &make, &cfg, 1, None).unwrap();
    let mut src8 = InMemorySource::from_trace(&trace);
    let eight = replay_source(&mut src8, &make, &cfg, 8, None).unwrap();

    assert!(!one.trace_events.is_empty());
    assert_eq!(
        one.trace_events.len() as u64,
        one.runs_replayed,
        "one instant per replayed run (warm-up and scored alike)"
    );
    assert_eq!(one, eight, "replay outcome incl. trace must not depend on shard count");
}

/// Per-shard metric registries can be merged in any order: counters
/// and histogram buckets are commutative sums, and the rendered
/// Prometheus exposition is identical either way.
#[test]
fn registry_merge_is_permutation_invariant() {
    let bounds = [1.0, 5.0, 10.0];
    let parts: Vec<Registry> = (0..6u64)
        .map(|i| {
            let mut r = Registry::new();
            r.counter_add("events_total", i + 1);
            r.observe("wait_s", &bounds, i as f64 * 2.0);
            r
        })
        .collect();

    let mut fwd = Registry::new();
    for p in &parts {
        fwd.merge(p);
    }
    let mut rev = Registry::new();
    for p in parts.iter().rev() {
        rev.merge(p);
    }

    assert_eq!(fwd, rev, "merge order must not matter");
    assert_eq!(fwd.counter("events_total"), 21);
    let h = fwd.histogram("wait_s").expect("histogram merged");
    assert_eq!(h.count(), 6);
    assert_eq!(h.sum(), 30.0);
    assert_eq!(fwd.to_prometheus(), rev.to_prometheus());
}

/// The acceptance criterion behind `schedule --dag sarek --fail-rate
/// 0.1 --trace-out run.json`: a failure-heavy DAG run produces a
/// Chrome trace JSON document that parses, carries the required
/// fields, and keeps its async spans balanced — every placement
/// (`'b'`) is closed by exactly one completion or kill (`'e'`).
#[test]
fn dag_run_with_failures_writes_valid_chrome_trace() {
    let path = temp_path("sarek_trace.json");
    let sink = ChromeTraceSink::create(path.to_str().unwrap()).unwrap();
    let mut tel = RunTelemetry::with_trace(Box::new(sink));

    let src = WorkflowSource::from_spec(&sarek_workflow(), 42, 3);
    let mut p = KSegmentsPredictor::native(4, RetryStrategy::Selective);
    let cfg = SchedConfig { seed: 42, fail_mtbf: Seconds(600.0), ..SchedConfig::default() };
    let (rep, _log) = schedule_workflows_telemetry(src, &mut p, &cfg, &mut tel);
    tel.finish().unwrap();
    assert!(rep.completed > 0);

    let doc = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let j = Json::parse(&doc).expect("trace file is valid JSON");
    let events = j.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty());

    let (mut begins, mut ends) = (0u64, 0u64);
    for ev in events {
        assert!(ev.get("name").as_str().is_some(), "every event is named");
        assert!(ev.get("cat").as_str().is_some());
        assert!(ev.get("ts").as_u64().is_some(), "timestamps are whole microseconds");
        assert!(ev.get("pid").as_u64().is_some());
        assert!(ev.get("tid").as_u64().is_some());
        match ev.get("ph").as_str().expect("phase present") {
            "b" => {
                assert!(ev.get("id").as_u64().is_some(), "span begins carry an id");
                begins += 1;
            }
            "e" => {
                assert!(ev.get("id").as_u64().is_some(), "span ends carry an id");
                ends += 1;
            }
            "i" => {}
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert!(begins > 0, "a scheduler run places tasks");
    assert_eq!(begins, ends, "every placed attempt must end exactly once");
    assert!(begins >= rep.completed, "each completion closes one placement span");
}

/// Provenance JSONL round-trip: every line parses, `predict` records
/// match submissions one-to-one, and `failure` records match OOM
/// escalations one-to-one.
#[test]
fn provenance_jsonl_parses_and_matches_report() {
    let path = temp_path("provenance.jsonl");
    let trace = generate_workflow_trace(&eager_workflow(), 7);
    let cfg = adversity_cfg(7);

    let mut tel = RunTelemetry::off();
    tel.provenance = Some(ProvenanceLog::create(path.to_str().unwrap()).unwrap());
    let mut p = KSegmentsPredictor::native(4, RetryStrategy::Selective);
    let (rep, _log) = schedule_trace_telemetry(&trace, &mut p, &cfg, &mut tel);
    tel.finish().unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let (mut predicts, mut failures) = (0u64, 0u64);
    for line in text.lines() {
        let j = Json::parse(line).expect("each provenance line is one JSON object");
        assert!(j.get("time_s").as_f64().is_some());
        assert!(j.get("task").as_str().is_some());
        match j.get("kind").as_str().expect("kind present") {
            "predict" => {
                assert!(j.get("alloc_mib").as_f64().unwrap() > 0.0);
                assert!(j.get("segments").as_u64().unwrap() >= 1);
                predicts += 1;
            }
            "failure" => {
                assert!(j.get("cause").as_str().is_some());
                assert!(j.get("new_alloc_mib").as_f64().is_some());
                failures += 1;
            }
            other => panic!("unknown record kind {other:?}"),
        }
    }
    assert_eq!(predicts, rep.submitted, "one predict record per submission");
    assert_eq!(failures, rep.oom_kills, "one failure record per OOM escalation");
}
