//! Trace persistence: CSV (one row per sample, like the paper's
//! published k-Segments-traces repository) and JSON-lines (one object
//! per run, convenient for tooling and the streaming
//! `JsonlReader` in the serve layer).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::{TaskRun, Trace, UsageSeries};
use crate::units::{MemMiB, Seconds};
use crate::util::json::Json;

/// One record of the JSONL trace format.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonlRecord {
    /// A developer-default allocation for a task type.
    Default { task_type: String, mem: MemMiB },
    /// One observed execution.
    Run(TaskRun),
}

fn default_record(task_type: &str, mem: MemMiB) -> Json {
    Json::obj(vec![
        ("kind", "default".into()),
        ("task_type", task_type.into()),
        ("default_mib", mem.0.into()),
    ])
}

/// The canonical JSON `run` record — the single shape
/// [`parse_jsonl_record`] accepts, shared by the trace writers, the
/// checkpoint writer and the network wire protocol so the formats
/// cannot drift apart.
pub fn run_record(run: &TaskRun) -> Json {
    Json::obj(vec![
        ("kind", "run".into()),
        ("task_type", run.task_type.as_str().into()),
        ("seq", run.seq.into()),
        ("input_mib", run.input_mib.into()),
        ("runtime_s", run.runtime.0.into()),
        ("interval_s", run.series.interval().0.into()),
        ("samples_mib", Json::arr_f64(run.series.samples())),
    ])
}

/// Parse and validate one line of the JSONL trace format.
///
/// Every malformed-record path errors here — unparseable JSON, missing
/// or mistyped fields, unknown `kind`, and physically impossible
/// values (negative `runtime_s` / `input_mib`, non-positive
/// `interval_s`, negative or non-finite samples). Callers attach the
/// line number via [`anyhow::Context`], so any malformed line is
/// reported with its position regardless of which check tripped.
pub fn parse_jsonl_record(line: &str) -> Result<JsonlRecord> {
    let rec = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    let kind = rec.get("kind").as_str().unwrap_or("");
    let ty = rec
        .get("task_type")
        .as_str()
        .context("missing task_type")?
        .to_string();
    match kind {
        "default" => {
            let mem = rec.get("default_mib").as_f64().context("default_mib")?;
            ensure!(
                mem.is_finite() && mem >= 0.0,
                "negative or non-finite default_mib {mem}"
            );
            Ok(JsonlRecord::Default { task_type: ty, mem: MemMiB(mem) })
        }
        "run" => Ok(JsonlRecord::Run(run_from_json(&rec)?)),
        other => bail!("unknown kind {other:?}"),
    }
}

/// Validate + convert an already-parsed JSON object into a
/// [`TaskRun`] — the shared kernel behind [`parse_jsonl_record`]'s
/// `run` arm and the network protocol's `complete`/`replay` request
/// frames. Accepts exactly the [`run_record`] shape; the `kind` field
/// is ignored here (the JSONL reader dispatches on it beforehand).
pub fn run_from_json(rec: &Json) -> Result<TaskRun> {
    let ty = rec
        .get("task_type")
        .as_str()
        .context("missing task_type")?
        .to_string();
    let runtime = rec.get("runtime_s").as_f64().context("runtime_s")?;
    ensure!(
        runtime.is_finite() && runtime >= 0.0,
        "negative or non-finite runtime_s {runtime}"
    );
    let interval = rec.get("interval_s").as_f64().context("interval_s")?;
    ensure!(
        interval.is_finite() && interval > 0.0,
        "non-positive or non-finite interval_s {interval}"
    );
    let input = rec.get("input_mib").as_f64().context("input_mib")?;
    ensure!(
        input.is_finite() && input >= 0.0,
        "negative or non-finite input_mib {input}"
    );
    let samples: Vec<f64> = rec
        .get("samples_mib")
        .as_arr()
        .context("samples_mib")?
        .iter()
        .map(|v| v.as_f64().context("non-numeric sample"))
        .collect::<Result<_>>()?;
    ensure!(
        samples.iter().all(|s| s.is_finite() && *s >= 0.0),
        "negative or non-finite sample in samples_mib"
    );
    Ok(TaskRun {
        task_type: ty,
        input_mib: input,
        runtime: Seconds(runtime),
        series: UsageSeries::new(interval, samples),
        seq: rec.get("seq").as_u64().context("seq")?,
    })
}

/// Write a trace as JSON lines: a `default` record per task type with a
/// configured default, then a `run` record per execution, grouped by
/// task type.
pub fn write_trace_jsonl(trace: &Trace, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).context("creating jsonl trace")?);
    for ty in trace.task_types().map(String::from).collect::<Vec<_>>() {
        if let Some(mem) = trace.default_alloc(&ty) {
            writeln!(w, "{}", default_record(&ty, mem))?;
        }
        for run in trace.runs_of(&ty) {
            writeln!(w, "{}", run_record(run))?;
        }
    }
    Ok(())
}

/// Write a trace as JSON lines in **replay order**: every `default`
/// record first (sorted by task type), then every run sorted by global
/// submission order (`seq`) — the order a streaming
/// [`crate::source::TraceSource`] yields, so a `ksegments ingest`
/// output file replays through `ksegments replay` and the scheduler's
/// arrival stream without re-sorting.
pub fn write_trace_jsonl_ordered(trace: &Trace, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).context("creating jsonl trace")?);
    for ty in trace.task_types() {
        if let Some(mem) = trace.default_alloc(ty) {
            writeln!(w, "{}", default_record(ty, mem))?;
        }
    }
    for run in trace.all_runs_ordered() {
        writeln!(w, "{}", run_record(run))?;
    }
    Ok(())
}

/// Read a JSONL trace written by [`write_trace_jsonl`] (or
/// [`write_trace_jsonl_ordered`]; record order does not matter — runs
/// are re-sorted by `seq` per type).
pub fn read_trace_jsonl(path: &Path) -> Result<Trace> {
    let r = BufReader::new(File::open(path).context("opening jsonl trace")?);
    let mut trace = Trace::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let rec = parse_jsonl_record(&line)
            .with_context(|| format!("jsonl line {}", lineno + 1))?;
        match rec {
            JsonlRecord::Default { task_type, mem } => trace.set_default(&task_type, mem),
            JsonlRecord::Run(run) => trace.push(run),
        }
    }
    trace.sort();
    Ok(trace)
}

/// Write a trace as CSV with one row per monitoring sample:
/// `task_type,seq,input_mib,runtime_s,interval_s,sample_idx,mem_mib`.
pub fn write_trace_csv(trace: &Trace, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).context("creating csv trace")?);
    writeln!(w, "task_type,seq,input_mib,runtime_s,interval_s,sample_idx,mem_mib")?;
    for ty in trace.task_types().map(String::from).collect::<Vec<_>>() {
        for run in trace.runs_of(&ty) {
            for (i, v) in run.series.samples().iter().enumerate() {
                writeln!(
                    w,
                    "{},{},{},{},{},{},{}",
                    run.task_type,
                    run.seq,
                    run.input_mib,
                    run.runtime.0,
                    run.series.interval().0,
                    i,
                    v
                )?;
            }
        }
    }
    Ok(())
}

/// Read a CSV trace written by [`write_trace_csv`].
pub fn read_trace_csv(path: &Path) -> Result<Trace> {
    let r = BufReader::new(File::open(path).context("opening csv trace")?);
    let mut lines = r.lines();
    let header = lines.next().transpose()?.unwrap_or_default();
    if !header.starts_with("task_type,seq,") {
        bail!("unrecognized trace csv header: {header:?}");
    }
    // accumulate rows into runs keyed by (type, seq)
    let mut current: Option<(String, u64, f64, f64, f64, Vec<f64>)> = None;
    let mut trace = Trace::new();
    fn flush(cur: &mut Option<(String, u64, f64, f64, f64, Vec<f64>)>, trace: &mut Trace) {
        if let Some((ty, seq, input, rt, iv, samples)) = cur.take() {
            trace.push(TaskRun {
                task_type: ty,
                input_mib: input,
                runtime: Seconds(rt),
                series: UsageSeries::new(iv, samples),
                seq,
            });
        }
    }
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 7 {
            bail!("csv line {}: expected 7 fields, got {}", lineno + 2, f.len());
        }
        let (ty, seq) = (f[0].to_string(), f[1].parse::<u64>()?);
        let (input, rt, iv) = (f[2].parse()?, f[3].parse()?, f[4].parse()?);
        let mem: f64 = f[6].parse()?;
        match &mut current {
            Some((cty, cseq, _, _, _, samples)) if *cty == ty && *cseq == seq => {
                samples.push(mem)
            }
            _ => {
                flush(&mut current, &mut trace);
                current = Some((ty, seq, input, rt, iv, vec![mem]));
            }
        }
    }
    flush(&mut current, &mut trace);
    trace.sort();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let mut t = Trace::new();
        t.set_default("wf/a", MemMiB(4096.0));
        for seq in 0..3u64 {
            t.push(TaskRun {
                task_type: "wf/a".into(),
                input_mib: 100.0 + seq as f64,
                runtime: Seconds(6.0),
                series: UsageSeries::new(2.0, vec![1.0, 5.0 + seq as f64, 2.0]),
                seq,
            });
        }
        t.push(TaskRun {
            task_type: "wf/b".into(),
            input_mib: 9.0,
            runtime: Seconds(2.0),
            series: UsageSeries::new(2.0, vec![7.0]),
            seq: 3,
        });
        t
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let t = sample_trace();
        write_trace_jsonl(&t, &path).unwrap();
        let back = read_trace_jsonl(&path).unwrap();
        assert_eq!(back.n_types(), 2);
        assert_eq!(back.n_runs(), 4);
        assert_eq!(back.runs_of("wf/a"), t.runs_of("wf/a"));
        assert_eq!(back.default_alloc("wf/a"), Some(MemMiB(4096.0)));
    }

    #[test]
    fn ordered_jsonl_roundtrips_and_streams_in_seq_order() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace_ordered.jsonl");
        let t = sample_trace();
        write_trace_jsonl_ordered(&t, &path).unwrap();
        // same trace back through the grouped reader
        let back = read_trace_jsonl(&path).unwrap();
        assert_eq!(back, t);
        // file order: defaults first, then runs by seq
        let text = std::fs::read_to_string(&path).unwrap();
        let kinds: Vec<bool> = text.lines().map(|l| l.contains("\"kind\":\"run\"")).collect();
        assert_eq!(kinds, vec![false, true, true, true, true]);
        let seqs: Vec<usize> = text
            .lines()
            .filter(|l| l.contains("\"kind\":\"run\""))
            .map(|l| match parse_jsonl_record(l).unwrap() {
                JsonlRecord::Run(r) => r.seq as usize,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(seqs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        let t = sample_trace();
        write_trace_csv(&t, &path).unwrap();
        let back = read_trace_csv(&path).unwrap();
        assert_eq!(back.n_runs(), 4);
        assert_eq!(back.runs_of("wf/b")[0].series.samples(), &[7.0]);
        // CSV does not carry defaults
        assert_eq!(back.default_alloc("wf/a"), None);
    }

    #[test]
    fn csv_rejects_bad_header() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "nope\n1,2,3\n").unwrap();
        assert!(read_trace_csv(&path).is_err());
    }

    #[test]
    fn jsonl_rejects_unknown_kind() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"kind\":\"wat\",\"task_type\":\"x\"}\n").unwrap();
        assert!(read_trace_jsonl(&path).is_err());
    }

    /// Regression: every malformed-record path must carry the line
    /// number, not just unparseable JSON (the original code attached it
    /// only to `Json::parse` failures).
    #[test]
    fn jsonl_errors_carry_line_numbers_on_all_paths() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let ok_default = "{\"kind\":\"default\",\"task_type\":\"a\",\"default_mib\":10}";
        let ok_run = "{\"kind\":\"run\",\"task_type\":\"a\",\"seq\":0,\"input_mib\":1,\
                      \"runtime_s\":4,\"interval_s\":2,\"samples_mib\":[1,2]}";
        let cases: &[(&str, &str)] = &[
            // (malformed third line, expected fragment)
            ("{not json", "json"),
            ("{\"kind\":\"run\",\"seq\":0}", "task_type"),
            ("{\"kind\":\"wat\",\"task_type\":\"x\"}", "unknown kind"),
            (
                "{\"kind\":\"run\",\"task_type\":\"a\",\"seq\":0,\"input_mib\":1,\
                 \"runtime_s\":-4,\"interval_s\":2,\"samples_mib\":[1]}",
                "runtime_s",
            ),
            (
                "{\"kind\":\"run\",\"task_type\":\"a\",\"seq\":0,\"input_mib\":1,\
                 \"runtime_s\":4,\"interval_s\":-2,\"samples_mib\":[1]}",
                "interval_s",
            ),
            (
                "{\"kind\":\"run\",\"task_type\":\"a\",\"seq\":0,\"input_mib\":1,\
                 \"runtime_s\":4,\"interval_s\":2,\"samples_mib\":[1,-3]}",
                "sample",
            ),
            ("{\"kind\":\"default\",\"task_type\":\"a\",\"default_mib\":-1}", "default_mib"),
        ];
        for (i, (bad, expect)) in cases.iter().enumerate() {
            let path = dir.join(format!("bad_line_{i}.jsonl"));
            std::fs::write(&path, format!("{ok_default}\n{ok_run}\n{bad}\n")).unwrap();
            let err = read_trace_jsonl(&path).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("line 3"), "case {i}: missing line number in {msg:?}");
            assert!(
                msg.to_lowercase().contains(&expect.to_lowercase()),
                "case {i}: missing {expect:?} in {msg:?}"
            );
        }
    }
}
