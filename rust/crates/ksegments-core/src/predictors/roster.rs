//! The predictor-zoo roster: CLI `--method` keys → predictor
//! constructors, the single source of truth shared by the figure
//! grids (`ksegments-sim`), the scheduler sweeps (`ksegments-sched`)
//! and the CLI's `--method` plumbing.
//!
//! Pre-split this lived in `ksegments_sim::figures`; it moved into the
//! core layer because the sched sweeps need it too and the crate DAG
//! (enforced by `ksegments-lint`'s `layering` pass) forbids a
//! sideways sched → sim edge. `figures` re-exports everything here, so
//! the historical paths keep compiling.

use crate::ml::fitter::KsegFitter;
use crate::parallel::PredictorFactory;
use crate::predictors::adaptive_k::AdaptiveKPredictor;
use crate::predictors::condor::CondorTriple;
use crate::predictors::default_config::DefaultConfigPredictor;
use crate::predictors::dynseg::DynSegPredictor;
use crate::predictors::ensemble::EnsemblePredictor;
use crate::predictors::ksegments::{KSegmentsConfig, KSegmentsPredictor, RetryStrategy};
use crate::predictors::lr_witt::LrWittPredictor;
use crate::predictors::ppm::PpmPredictor;
use crate::predictors::MemoryPredictor;

/// Which backend the k-Segments fit runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitterChoice {
    /// Pure-rust mirror (always available).
    Native,
    /// AOT JAX + Pallas module via PJRT (requires `make artifacts`).
    Xla,
}

/// A k-Segments predictor at an explicit `k` on the chosen fit
/// backend — the parameterized constructor behind the two fixed-`k`
/// roster keys, exported for the fig-4/fig-8 `k` sweeps.
// The degraded-mode warning below is one of the two sanctioned stderr
// sites in this crate (the other is the equivalent fallback inside
// `runtime`): a silent fallback would misattribute XLA-vs-native
// results, and core has no logging facility by design.
#[allow(clippy::print_stderr)]
pub fn make_ksegments(
    choice: FitterChoice,
    k: usize,
    strategy: RetryStrategy,
) -> Box<dyn MemoryPredictor> {
    match choice {
        FitterChoice::Native => Box::new(KSegmentsPredictor::native(k, strategy)),
        FitterChoice::Xla => {
            let fitter: Box<dyn KsegFitter> = match crate::runtime::XlaFitter::load_default() {
                Ok(f) => Box::new(f),
                Err(e) => {
                    eprintln!("warning: XLA fitter unavailable ({e:#}); using native fit");
                    Box::new(crate::ml::fitter::NativeFitter)
                }
            };
            let cfg = KSegmentsConfig { k, ..KSegmentsConfig::default() };
            Box::new(KSegmentsPredictor::with_fitter(fitter, cfg, strategy))
        }
    }
}

/// CLI keys of the Fig. 7 predictor-zoo roster, in table-row order:
/// the paper's §IV-C lineup plus the follow-up-literature competitors
/// (Sizey ensemble, KS+ dynamic segmentation) and the HTCondor
/// `3 * MemoryUsage` production heuristic.
pub const METHOD_KEYS: &[&str] = &[
    "default",
    "ppm",
    "ppm-improved",
    "lr",
    "ksegments-selective",
    "ksegments-partial",
    "ensemble",
    "dynseg",
    "condor",
];

/// Keys accepted by `--method` but not part of the default roster.
pub const EXTRA_METHOD_KEYS: &[&str] = &["ksegments-adaptive"];

/// Build one predictor by CLI key (`None` for unknown keys). The
/// single source of truth for key → predictor, shared by the roster,
/// the grid factories, and the CLI's `--method` plumbing.
pub fn make_method(key: &str, choice: FitterChoice) -> Option<Box<dyn MemoryPredictor>> {
    Some(match key {
        "default" => Box::new(DefaultConfigPredictor::new()),
        "ppm" => Box::new(PpmPredictor::original()),
        "ppm-improved" => Box::new(PpmPredictor::improved()),
        "lr" => Box::new(LrWittPredictor::paper_baseline()),
        "ksegments-selective" => make_ksegments(choice, 4, RetryStrategy::Selective),
        "ksegments-partial" => make_ksegments(choice, 4, RetryStrategy::Partial),
        "ksegments-adaptive" => Box::new(AdaptiveKPredictor::native(RetryStrategy::Selective)),
        "ensemble" => Box::new(EnsemblePredictor::new()),
        "dynseg" => Box::new(DynSegPredictor::native(4, RetryStrategy::Selective)),
        "condor" => Box::new(CondorTriple::new()),
        _ => return None,
    })
}

/// Resolve a `--method` selection — `"all"`, one key, or a comma list —
/// into canonical roster keys (errors on unknown names).
pub fn resolve_methods(selection: &str) -> Result<Vec<&'static str>, String> {
    if selection == "all" {
        return Ok(METHOD_KEYS.to_vec());
    }
    let mut out = Vec::new();
    for part in selection.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let key = METHOD_KEYS
            .iter()
            .chain(EXTRA_METHOD_KEYS)
            .find(|k| **k == part)
            .ok_or_else(|| {
                format!(
                    "unknown method {part:?} (expected \"all\" or any of: {}, {})",
                    METHOD_KEYS.join(", "),
                    EXTRA_METHOD_KEYS.join(", ")
                )
            })?;
        out.push(*key);
    }
    if out.is_empty() {
        return Err("empty method selection".into());
    }
    Ok(out)
}

/// Thread-safe factories for a resolved key list, in the given order.
pub fn makers_for_keys(keys: &[&'static str], choice: FitterChoice) -> Vec<PredictorFactory> {
    keys.iter()
        .map(|&key| {
            // membership check only — constructing a predictor here
            // would load (and drop) the XLA artifacts once per key
            assert!(
                METHOD_KEYS.contains(&key) || EXTRA_METHOD_KEYS.contains(&key),
                "unresolved method key {key:?}"
            );
            Box::new(move || make_method(key, choice).expect("resolved key")) as PredictorFactory
        })
        .collect()
}

/// The full Fig. 7 method roster (paper §IV-C + the predictor zoo).
pub fn method_roster(choice: FitterChoice) -> Vec<Box<dyn MemoryPredictor>> {
    METHOD_KEYS
        .iter()
        .map(|k| make_method(k, choice).expect("roster key"))
        .collect()
}

/// Names in roster order (stable across runs; used by tables).
pub fn method_names() -> Vec<String> {
    method_roster(FitterChoice::Native)
        .iter()
        .map(|m| m.name())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_roster_key_constructs() {
        for key in METHOD_KEYS.iter().chain(EXTRA_METHOD_KEYS) {
            assert!(make_method(key, FitterChoice::Native).is_some(), "key {key:?}");
        }
        assert!(make_method("no-such-method", FitterChoice::Native).is_none());
    }

    #[test]
    fn resolve_methods_all_and_lists() {
        assert_eq!(resolve_methods("all").unwrap(), METHOD_KEYS.to_vec());
        assert_eq!(resolve_methods("dynseg, condor").unwrap(), vec!["dynseg", "condor"]);
        assert!(resolve_methods("nope").is_err());
        assert!(resolve_methods("").is_err());
    }

    #[test]
    fn makers_build_the_named_method() {
        let makers = makers_for_keys(&["ppm-improved", "condor"], FitterChoice::Native);
        assert_eq!(makers.len(), 2);
        let names: Vec<String> = makers.iter().map(|mk| mk().name()).collect();
        assert_eq!(names, method_names_for(&["ppm-improved", "condor"]));
    }

    fn method_names_for(keys: &[&str]) -> Vec<String> {
        keys.iter()
            .map(|k| make_method(k, FitterChoice::Native).unwrap().name())
            .collect()
    }
}
