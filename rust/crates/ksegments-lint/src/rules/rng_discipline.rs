//! `rng-discipline`: randomness is replayable only when every stream
//! descends from the run's `--seed` root through `Rng::fork(label)`.
//! A literal seed baked into non-test code (`Rng::new(42)`) silently
//! decouples that code path from the seed the experiment records, so
//! it is banned outside `#[cfg(test)]` (tests pin literal seeds on
//! purpose).

use super::{FileCtx, Rule};
use crate::diag::Diagnostic;

pub struct RngDiscipline;

/// True when the text after `Rng::new(` starts with a numeric literal.
fn literal_arg(after: &str) -> bool {
    after.trim_start().starts_with(|c: char| c.is_ascii_digit())
}

impl Rule for RngDiscipline {
    fn id(&self) -> &'static str {
        "rng-discipline"
    }

    fn check(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        for (idx, line) in ctx.file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let mut rest = line.code.as_str();
            while let Some(pos) = rest.find("Rng::new(") {
                let after = &rest[pos + "Rng::new(".len()..];
                if literal_arg(after) {
                    out.push(Diagnostic {
                        rule: self.id(),
                        path: ctx.display_path.to_string(),
                        line: idx + 1,
                        message: "literal RNG seed; the root seed comes from --seed \
                                  and every stream from Rng::fork(label)"
                            .to_string(),
                    });
                }
                rest = after;
            }
        }
    }
}
