//! Minimal JSON reader/writer.
//!
//! The offline crate cache has no `serde_json`, and this crate only
//! needs JSON in two places it fully controls: `artifacts/manifest.json`
//! (written by `python/compile/aot.py`) and the JSONL trace format.
//! This is a complete, strict JSON implementation for those paths —
//! objects, arrays, strings (with escapes), numbers, bool, null.

use std::collections::BTreeMap;
use std::fmt;
use std::io;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our formats)
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy raw UTF-8 bytes through
                    let start = self.i;
                    let width = utf8_width(c);
                    if self.i + width > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.i += width;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    /// Compact serialization (no spaces), deterministic key order.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Streaming JSON serializer over any [`io::Write`] — no intermediate
/// [`Json`] tree, so arbitrarily long trace/provenance streams cost
/// O(1) memory. Emits the exact same compact grammar as the [`Json`]
/// [`fmt::Display`] impl (same escaping, same integer-vs-float number
/// formatting), so anything it writes round-trips through
/// [`Json::parse`]; the `writer_matches_tree_display` test pins the
/// equivalence.
///
/// Commas and `key:` separators are inserted automatically from a
/// container stack; the caller just issues `begin_obj`/`key`/values in
/// document order. Malformed call sequences (a value where a key is
/// required) are the caller's bug, not checked here.
pub struct JsonWriter<W: io::Write> {
    w: W,
    /// Items already written in each open container (for commas).
    stack: Vec<usize>,
    /// A `key(..)` was just written; the next value needs no comma.
    after_key: bool,
}

impl<W: io::Write> JsonWriter<W> {
    pub fn new(w: W) -> JsonWriter<W> {
        JsonWriter { w, stack: Vec::new(), after_key: false }
    }

    /// Comma/colon bookkeeping before any value or key.
    fn sep(&mut self) -> io::Result<()> {
        if self.after_key {
            self.after_key = false;
            return Ok(());
        }
        if let Some(n) = self.stack.last_mut() {
            if *n > 0 {
                self.w.write_all(b",")?;
            }
            *n += 1;
        }
        Ok(())
    }

    pub fn begin_obj(&mut self) -> io::Result<()> {
        self.sep()?;
        self.stack.push(0);
        self.w.write_all(b"{")
    }

    pub fn end_obj(&mut self) -> io::Result<()> {
        self.stack.pop();
        self.w.write_all(b"}")
    }

    pub fn begin_arr(&mut self) -> io::Result<()> {
        self.sep()?;
        self.stack.push(0);
        self.w.write_all(b"[")
    }

    pub fn end_arr(&mut self) -> io::Result<()> {
        self.stack.pop();
        self.w.write_all(b"]")
    }

    pub fn key(&mut self, k: &str) -> io::Result<()> {
        self.sep()?;
        write_json_str(&mut self.w, k)?;
        self.w.write_all(b":")?;
        self.after_key = true;
        Ok(())
    }

    pub fn str_val(&mut self, s: &str) -> io::Result<()> {
        self.sep()?;
        write_json_str(&mut self.w, s)
    }

    pub fn f64_val(&mut self, n: f64) -> io::Result<()> {
        self.sep()?;
        write_json_f64(&mut self.w, n)
    }

    pub fn u64_val(&mut self, n: u64) -> io::Result<()> {
        self.sep()?;
        write!(self.w, "{n}")
    }

    pub fn bool_val(&mut self, b: bool) -> io::Result<()> {
        self.sep()?;
        write!(self.w, "{b}")
    }

    pub fn null_val(&mut self) -> io::Result<()> {
        self.sep()?;
        self.w.write_all(b"null")
    }

    // -- `key: value` conveniences ----------------------------------------

    pub fn field_str(&mut self, k: &str, v: &str) -> io::Result<()> {
        self.key(k)?;
        self.str_val(v)
    }

    pub fn field_f64(&mut self, k: &str, v: f64) -> io::Result<()> {
        self.key(k)?;
        self.f64_val(v)
    }

    pub fn field_u64(&mut self, k: &str, v: u64) -> io::Result<()> {
        self.key(k)?;
        self.u64_val(v)
    }

    pub fn field_bool(&mut self, k: &str, v: bool) -> io::Result<()> {
        self.key(k)?;
        self.bool_val(v)
    }

    /// Flush and return the underlying writer.
    pub fn finish(mut self) -> io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Escape + quote a string exactly like the [`Json`] Display impl.
pub fn write_json_str<W: io::Write>(w: &mut W, s: &str) -> io::Result<()> {
    w.write_all(b"\"")?;
    for c in s.chars() {
        match c {
            '"' => w.write_all(b"\\\"")?,
            '\\' => w.write_all(b"\\\\")?,
            '\n' => w.write_all(b"\\n")?,
            '\r' => w.write_all(b"\\r")?,
            '\t' => w.write_all(b"\\t")?,
            c if (c as u32) < 0x20 => write!(w, "\\u{:04x}", c as u32)?,
            c => write!(w, "{c}")?,
        }
    }
    w.write_all(b"\"")
}

/// Format a number exactly like the [`Json`] Display impl: integral
/// values below 1e15 print without a fractional part.
pub fn write_json_f64<W: io::Write>(w: &mut W, n: f64) -> io::Result<()> {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        write!(w, "{}", n as i64)
    } else {
        write!(w, "{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x"));
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"GB·s ümlaut\"").unwrap();
        assert_eq!(v.as_str(), Some("GB·s ümlaut"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("treu").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"fits":{"1":"a.txt","2":"b.txt"},"n":64,"neg":-1.5,"ok":true,"s":"x\"y"}"#;
        let v = Json::parse(src).unwrap();
        let ser = v.to_string();
        assert_eq!(Json::parse(&ser).unwrap(), v);
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None);
    }

    #[test]
    fn builders_and_display() {
        let j = Json::obj(vec![("k", Json::arr_f64(&[1.0, 2.5])), ("b", true.into())]);
        assert_eq!(j.to_string(), r#"{"b":true,"k":[1,2.5]}"#);
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert_eq!(Json::Num(1.0).get("x"), &Json::Null);
    }

    #[test]
    fn whitespace_tolerance() {
        let v = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn writer_streams_nested_document() {
        let mut w = JsonWriter::new(Vec::new());
        w.begin_obj().unwrap();
        w.field_str("name", "run").unwrap();
        w.field_u64("n", 3).unwrap();
        w.key("xs").unwrap();
        w.begin_arr().unwrap();
        w.f64_val(1.0).unwrap();
        w.f64_val(2.5).unwrap();
        w.begin_obj().unwrap();
        w.field_bool("ok", true).unwrap();
        w.key("none").unwrap();
        w.null_val().unwrap();
        w.end_obj().unwrap();
        w.end_arr().unwrap();
        w.end_obj().unwrap();
        let out = String::from_utf8(w.finish().unwrap()).unwrap();
        assert_eq!(out, r#"{"name":"run","n":3,"xs":[1,2.5,{"ok":true,"none":null}]}"#);
    }

    #[test]
    fn writer_output_roundtrips_through_parse() {
        let mut w = JsonWriter::new(Vec::new());
        w.begin_obj().unwrap();
        w.field_str("esc", "a\"b\\c\nd\te\u{1}").unwrap();
        w.field_f64("f", -3.25).unwrap();
        w.field_f64("i", 7.0).unwrap();
        w.field_u64("big", 1_234_567_890_123).unwrap();
        w.end_obj().unwrap();
        let out = String::from_utf8(w.finish().unwrap()).unwrap();
        let v = Json::parse(&out).unwrap();
        assert_eq!(v.get("esc").as_str(), Some("a\"b\\c\nd\te\u{1}"));
        assert_eq!(v.get("f").as_f64(), Some(-3.25));
        assert_eq!(v.get("i").as_f64(), Some(7.0));
        assert_eq!(v.get("big").as_u64(), Some(1_234_567_890_123));
    }

    #[test]
    fn writer_matches_tree_display() {
        // the streaming writer and the Json tree Display must emit the
        // same bytes for the same document (escaping + number format)
        let tricky = "GB·s \"x\"\\\n\t\u{2}";
        let tree = Json::obj(vec![
            ("a", Json::arr_f64(&[1.0, 2.5, -0.0])),
            ("s", tricky.into()),
            ("n", 42u64.into()),
        ]);
        let mut w = JsonWriter::new(Vec::new());
        w.begin_obj().unwrap();
        w.key("a").unwrap();
        w.begin_arr().unwrap();
        w.f64_val(1.0).unwrap();
        w.f64_val(2.5).unwrap();
        w.f64_val(-0.0).unwrap();
        w.end_arr().unwrap();
        w.field_u64("n", 42).unwrap();
        w.field_str("s", tricky).unwrap();
        w.end_obj().unwrap();
        let streamed = String::from_utf8(w.finish().unwrap()).unwrap();
        assert_eq!(streamed, tree.to_string());
    }

    #[test]
    fn writer_top_level_scalar_and_empty_containers() {
        let mut w = JsonWriter::new(Vec::new());
        w.begin_arr().unwrap();
        w.begin_obj().unwrap();
        w.end_obj().unwrap();
        w.begin_arr().unwrap();
        w.end_arr().unwrap();
        w.str_val("x").unwrap();
        w.end_arr().unwrap();
        let out = String::from_utf8(w.finish().unwrap()).unwrap();
        assert_eq!(out, r#"[{},[],"x"]"#);
        assert!(Json::parse(&out).is_ok());
    }
}
