//! Task-execution traces: the data model shared by the monitoring
//! pipeline, the predictors, and the simulator.
//!
//! A [`UsageSeries`] is what the paper's monitoring extension records
//! per task container (cgroup memory samples at a fixed interval); a
//! [`TaskRun`] bundles one execution's series with its metadata (total
//! input size, runtime); a [`Trace`] is the per-task-type ordered
//! collection the online simulator replays.

mod io;
mod series;

pub use io::{
    parse_jsonl_record, read_trace_csv, read_trace_jsonl, run_from_json, run_record,
    write_trace_csv, write_trace_jsonl, write_trace_jsonl_ordered, JsonlRecord,
};
pub use series::UsageSeries;

use std::collections::BTreeMap;

use crate::units::{MemMiB, Seconds};

/// One observed execution of a workflow task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskRun {
    /// Task type name, e.g. `"eager/adapter_removal"`.
    pub task_type: String,
    /// Total size of all input files (the predictor's independent
    /// variable, paper §III-B).
    pub input_mib: f64,
    /// Wall-clock runtime of the successful execution.
    pub runtime: Seconds,
    /// Interval-sampled memory usage over the execution.
    pub series: UsageSeries,
    /// Global submission order within the workflow execution — the
    /// online simulator replays runs in this order.
    pub seq: u64,
}

impl TaskRun {
    /// Peak memory over the whole execution (what static baselines learn).
    pub fn peak(&self) -> MemMiB {
        MemMiB(self.series.peak())
    }
}

/// An ordered collection of task runs, grouped by task type.
///
/// # Example
///
/// ```
/// use ksegments::trace::{TaskRun, Trace, UsageSeries};
/// use ksegments::units::Seconds;
///
/// let mut trace = Trace::new();
/// trace.push(TaskRun {
///     task_type: "wf/align".into(),
///     input_mib: 512.0,
///     runtime: Seconds(4.0),
///     series: UsageSeries::new(2.0, vec![100.0, 180.0]),
///     seq: 0,
/// });
/// assert_eq!(trace.n_runs(), 1);
/// assert_eq!(trace.runs_of("wf/align")[0].peak().0, 180.0);
/// assert_eq!(trace.task_types().collect::<Vec<_>>(), vec!["wf/align"]);
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Per task type, runs sorted by `seq`. BTreeMap keeps iteration
    /// order deterministic across platforms.
    runs: BTreeMap<String, Vec<TaskRun>>,
    /// Workflow developer defaults (paper's sanity baseline): the static
    /// allocation used when running the workflow out of the box.
    defaults: BTreeMap<String, MemMiB>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, run: TaskRun) {
        self.runs.entry(run.task_type.clone()).or_default().push(run);
    }

    /// Record the workflow developers' default allocation for a type.
    pub fn set_default(&mut self, task_type: &str, mem: MemMiB) {
        self.defaults.insert(task_type.to_string(), mem);
    }

    pub fn default_alloc(&self, task_type: &str) -> Option<MemMiB> {
        self.defaults.get(task_type).copied()
    }

    pub fn task_types(&self) -> impl Iterator<Item = &str> {
        self.runs.keys().map(String::as_str)
    }

    pub fn runs_of(&self, task_type: &str) -> &[TaskRun] {
        self.runs.get(task_type).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn n_types(&self) -> usize {
        self.runs.len()
    }

    pub fn n_runs(&self) -> usize {
        self.runs.values().map(Vec::len).sum()
    }

    /// All runs across types, sorted by submission order — the replay
    /// order of the online evaluation protocol.
    pub fn all_runs_ordered(&self) -> Vec<&TaskRun> {
        let mut all: Vec<&TaskRun> = self.runs.values().flatten().collect();
        all.sort_by_key(|r| r.seq);
        all
    }

    /// Finalize: sort each type's runs by sequence number.
    pub fn sort(&mut self) {
        for runs in self.runs.values_mut() {
            runs.sort_by_key(|r| r.seq);
        }
    }

    /// Restrict to task types satisfying `keep` (used by the Fig. 8
    /// per-task sweeps).
    pub fn filtered(&self, keep: impl Fn(&str) -> bool) -> Trace {
        Trace {
            runs: self
                .runs
                .iter()
                .filter(|(k, _)| keep(k))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
            defaults: self
                .defaults
                .iter()
                .filter(|(k, _)| keep(k))
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
        }
    }

    /// Task types with at least `min_runs` executions — the paper
    /// evaluates the 33 types that have enough history to learn from.
    pub fn evaluated_types(&self, min_runs: usize) -> Vec<&str> {
        self.runs
            .iter()
            .filter(|(_, v)| v.len() >= min_runs)
            .map(|(k, _)| k.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(task: &str, seq: u64, peak: f64) -> TaskRun {
        TaskRun {
            task_type: task.to_string(),
            input_mib: 100.0,
            runtime: Seconds(10.0),
            series: UsageSeries::new(2.0, vec![peak / 2.0, peak, peak / 4.0]),
            seq,
        }
    }

    #[test]
    fn push_and_group() {
        let mut t = Trace::new();
        t.push(run("a", 0, 100.0));
        t.push(run("b", 1, 50.0));
        t.push(run("a", 2, 200.0));
        assert_eq!(t.n_types(), 2);
        assert_eq!(t.n_runs(), 3);
        assert_eq!(t.runs_of("a").len(), 2);
        assert_eq!(t.runs_of("missing").len(), 0);
    }

    #[test]
    fn ordered_replay() {
        let mut t = Trace::new();
        t.push(run("a", 5, 1.0));
        t.push(run("b", 2, 1.0));
        t.push(run("a", 9, 1.0));
        let seqs: Vec<u64> = t.all_runs_ordered().iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![2, 5, 9]);
    }

    #[test]
    fn peak_of_run() {
        assert_eq!(run("a", 0, 80.0).peak(), MemMiB(80.0));
    }

    #[test]
    fn evaluated_types_threshold() {
        let mut t = Trace::new();
        for i in 0..5 {
            t.push(run("common", i, 1.0));
        }
        t.push(run("rare", 99, 1.0));
        assert_eq!(t.evaluated_types(3), vec!["common"]);
        assert_eq!(t.evaluated_types(1).len(), 2);
    }

    #[test]
    fn defaults_roundtrip() {
        let mut t = Trace::new();
        t.set_default("a", MemMiB::from_gib(8.0));
        assert_eq!(t.default_alloc("a"), Some(MemMiB(8192.0)));
        assert_eq!(t.default_alloc("b"), None);
    }
}
