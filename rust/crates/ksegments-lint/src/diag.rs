//! Diagnostics and the two report renderers.
//!
//! The JSON form is schema-stable (`ksegments-lint-v1`) so CI can
//! archive it and `tools/lint_check.py` can diff runs, exactly like
//! the bench snapshot flow. Ordering is deterministic: violations and
//! suppressions sort by (path, line, rule).

use std::fmt::Write as _;

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Rule id, e.g. `wallclock`.
    pub rule: &'static str,
    /// Workspace-relative path, e.g. `crates/ksegments-core/src/rng.rs`.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

/// A finding that a `lint:allow(rule)` converted into a non-violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Suppression {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
}

fn sort_key<'a>(rule: &'a str, path: &'a str, line: usize) -> (&'a str, usize, &'a str) {
    (path, line, rule)
}

pub(crate) fn sort_diags(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| sort_key(a.rule, &a.path, a.line).cmp(&sort_key(b.rule, &b.path, b.line)));
}

pub(crate) fn sort_suppressions(sups: &mut [Suppression]) {
    sups.sort_by(|a, b| sort_key(a.rule, &a.path, a.line).cmp(&sort_key(b.rule, &b.path, b.line)));
}

/// `path:line: [rule] message` lines plus a one-line summary.
pub fn render_human(report: &crate::Report) -> String {
    let mut out = String::new();
    for d in &report.diags {
        let _ = writeln!(out, "{}:{}: [{}] {}", d.path, d.line, d.rule, d.message);
    }
    let _ = writeln!(
        out,
        "{} violation(s), {} suppression(s), {} file(s) scanned",
        report.diags.len(),
        report.suppressed.len(),
        report.files_scanned
    );
    out
}

/// Minimal JSON string escaping (the report contains paths and short
/// ASCII messages; anything exotic still escapes correctly).
fn json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// The `ksegments-lint-v1` report document.
pub fn render_json(report: &crate::Report) -> String {
    let mut out = String::new();
    out.push_str("{\"schema\":\"ksegments-lint-v1\"");
    let _ = write!(out, ",\"files_scanned\":{}", report.files_scanned);
    out.push_str(",\"rules\":[");
    for (i, r) in crate::rules::RULE_IDS.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        json_str(&mut out, r);
    }
    out.push_str("],\"violations\":[");
    for (i, d) in report.diags.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"rule\":");
        json_str(&mut out, d.rule);
        out.push_str(",\"path\":");
        json_str(&mut out, &d.path);
        let _ = write!(out, ",\"line\":{}", d.line);
        out.push_str(",\"message\":");
        json_str(&mut out, &d.message);
        out.push('}');
    }
    out.push_str("],\"suppressions\":[");
    for (i, s) in report.suppressed.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"rule\":");
        json_str(&mut out, s.rule);
        out.push_str(",\"path\":");
        json_str(&mut out, &s.path);
        let _ = write!(out, ",\"line\":{}", s.line);
        out.push('}');
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes_specials() {
        let mut s = String::new();
        json_str(&mut s, "a\"b\\c\nd");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn diags_sort_by_path_line_rule() {
        let mut ds = vec![
            Diagnostic { rule: "b", path: "z.rs".into(), line: 1, message: String::new() },
            Diagnostic { rule: "a", path: "a.rs".into(), line: 9, message: String::new() },
            Diagnostic { rule: "a", path: "a.rs".into(), line: 2, message: String::new() },
        ];
        sort_diags(&mut ds);
        assert_eq!(
            ds.iter().map(|d| (d.path.as_str(), d.line)).collect::<Vec<_>>(),
            vec![("a.rs", 2), ("a.rs", 9), ("z.rs", 1)]
        );
    }
}
