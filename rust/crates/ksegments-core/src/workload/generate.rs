//! Trace generation: turns a [`WorkflowSpec`] into a [`Trace`] of
//! interval-sampled executions, deterministically from a seed.

use crate::rng::Rng;
use crate::trace::{TaskRun, Trace, UsageSeries};
use crate::workload::spec::{TaskTypeSpec, WorkflowSpec};

/// Monitoring interval of the synthetic sampler — the paper's default
/// of 2 seconds (§IV-A).
pub const MONITOR_INTERVAL_S: f64 = 2.0;

/// Hard cap on samples per run (a 4 h run at 2 s is 7200 samples; the
/// cap only guards against pathological noise draws).
const MAX_SAMPLES: usize = 20_000;

/// Ground-truth usage curve for one execution: the type's temporal
/// profile scaled to this run's peak, with per-sample multiplicative
/// wiggle. Returned as interval samples (MiB).
pub fn ground_truth_curve(
    spec: &TaskTypeSpec,
    peak_mib: f64,
    runtime_s: f64,
    interval_s: f64,
    rng: &mut Rng,
) -> Vec<f64> {
    let n = ((runtime_s / interval_s).ceil() as usize).clamp(1, MAX_SAMPLES);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // mid-interval phase; the final sample can sit at phase 1.0
        let phase = ((i as f64 + 0.5) * interval_s / runtime_s).min(1.0);
        let rel = spec.profile.value(phase);
        let wiggle = 1.0 + spec.wiggle_sigma * rng.normal();
        out.push((peak_mib * rel * wiggle.max(0.2)).max(0.5));
    }
    out
}

/// Synthesize one execution of a task type from an already-forked rng
/// stream: input size, noised runtime and peak (with the occasional
/// data-dependent blowup), and the interval-sampled ground-truth
/// curve. Shared by [`generate_workflow_trace`] (wave-interleaved
/// traces) and the sched layer's `WorkflowSource` (per-instance DAG
/// executions) so both draw from the same distributions.
pub fn synth_execution(spec: &TaskTypeSpec, rng: &mut Rng, seq: u64) -> TaskRun {
    let input_mib = rng.lognormal(spec.input_mu, spec.input_sigma);
    let rt_noise = (spec.noise_sigma * rng.normal()).exp();
    let runtime_s =
        ((spec.rt_base.0 + spec.rt_per_mib * input_mib) * rt_noise).max(MONITOR_INTERVAL_S);
    let peak_noise = (spec.noise_sigma * rng.normal()).exp();
    // occasional data-dependent blowup (heavy tail; see spec)
    let spike = if rng.f64() < spec.spike_prob {
        rng.uniform(1.2, 1.45)
    } else {
        1.0
    };
    let peak_mib = (spec.peak_base.0 + spec.peak_per_mib * input_mib) * peak_noise * spike;

    let samples = ground_truth_curve(spec, peak_mib, runtime_s, MONITOR_INTERVAL_S, rng);
    let series = UsageSeries::new(MONITOR_INTERVAL_S, samples);
    // runtime := j·f, consistent with the paper's runtime model
    let runtime = series.duration();
    TaskRun { task_type: spec.name.clone(), input_mib, runtime, series, seq }
}

/// Generate the full trace of one workflow execution.
///
/// Executions are interleaved in waves that respect the DAG's
/// topological levels (upstream types start earlier), mirroring how a
/// SWMS releases ready tasks — this is what makes the *online*
/// evaluation protocol meaningful: by the time a downstream type is
/// scored, its earlier executions (and upstream ones) have been
/// observed.
pub fn generate_workflow_trace(wf: &WorkflowSpec, seed: u64) -> Trace {
    wf.validate().expect("invalid workflow spec");
    let root = Rng::new(seed).fork(&wf.name);

    // Rank types by topological level for wave ordering.
    let levels = wf.levels();
    let mut level_of = vec![0usize; wf.tasks.len()];
    for (lvl, members) in levels.iter().enumerate() {
        for &m in members {
            level_of[m] = lvl;
        }
    }
    let mut order: Vec<usize> = (0..wf.tasks.len()).collect();
    order.sort_by_key(|&i| (level_of[i], i));

    let mut trace = Trace::new();
    for t in &wf.tasks {
        trace.set_default(&t.name, t.default_mem);
    }

    let max_exec = wf.tasks.iter().map(|t| t.n_executions).max().unwrap_or(0);
    let mut seq: u64 = 0;
    for wave in 0..max_exec {
        for &ti in &order {
            let spec = &wf.tasks[ti];
            if wave >= spec.n_executions {
                continue;
            }
            let mut rng = root.fork(&format!("{}#{}", spec.name, wave));
            trace.push(synth_execution(spec, &mut rng, seq));
            seq += 1;
        }
    }
    trace.sort();
    trace
}

/// Convenience: generate both paper workflows into one trace set.
pub fn generate_paper_traces(seed: u64) -> Vec<(String, Trace)> {
    use crate::workload::catalog::{eager_workflow, sarek_workflow};
    vec![
        ("eager".to_string(), generate_workflow_trace(&eager_workflow(), seed)),
        ("sarek".to_string(), generate_workflow_trace(&sarek_workflow(), seed)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::{MemMiB, Seconds};
    use crate::workload::catalog::{eager_workflow, sarek_workflow};
    use crate::workload::profiles::ProfileShape;

    fn small_spec() -> TaskTypeSpec {
        TaskTypeSpec {
            name: "w/t".into(),
            profile: ProfileShape::RampUp { alpha: 1.0 },
            rt_base: Seconds(20.0),
            rt_per_mib: 0.05,
            peak_base: MemMiB(100.0),
            peak_per_mib: 0.5,
            noise_sigma: 0.1,
            spike_prob: 0.0,
            wiggle_sigma: 0.02,
            input_mu: 6.0,
            input_sigma: 0.5,
            n_executions: 30,
            default_mem: MemMiB(4096.0),
        }
    }

    #[test]
    fn curve_has_expected_length_and_positivity() {
        let mut rng = Rng::new(1);
        let c = ground_truth_curve(&small_spec(), 500.0, 100.0, 2.0, &mut rng);
        assert_eq!(c.len(), 50);
        assert!(c.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn curve_peak_close_to_target() {
        let mut rng = Rng::new(2);
        let c = ground_truth_curve(&small_spec(), 1000.0, 200.0, 2.0, &mut rng);
        let peak = c.iter().copied().fold(0.0, f64::max);
        assert!((peak - 1000.0).abs() / 1000.0 < 0.15, "peak={peak}");
    }

    #[test]
    fn trace_generation_is_deterministic() {
        let wf = eager_workflow();
        let a = generate_workflow_trace(&wf, 42);
        let b = generate_workflow_trace(&wf, 42);
        assert_eq!(a.n_runs(), b.n_runs());
        for ty in a.task_types() {
            assert_eq!(a.runs_of(ty), b.runs_of(ty), "type {ty}");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let wf = eager_workflow();
        let a = generate_workflow_trace(&wf, 1);
        let b = generate_workflow_trace(&wf, 2);
        let ra = &a.runs_of("eager/fastqc")[0];
        let rb = &b.runs_of("eager/fastqc")[0];
        assert_ne!(ra.input_mib, rb.input_mib);
    }

    #[test]
    fn execution_counts_match_spec() {
        let wf = eager_workflow();
        let t = generate_workflow_trace(&wf, 7);
        for spec in &wf.tasks {
            assert_eq!(t.runs_of(&spec.name).len(), spec.n_executions, "{}", spec.name);
        }
    }

    #[test]
    fn defaults_never_fail() {
        // The paper's Fig. 7c: the default baseline has zero retries.
        for (name, trace) in generate_paper_traces(42) {
            for ty in trace.task_types().map(String::from).collect::<Vec<_>>() {
                let default = trace.default_alloc(&ty).unwrap();
                for run in trace.runs_of(&ty) {
                    assert!(
                        run.peak().0 <= default.0,
                        "{name}/{ty} seq {}: peak {} exceeds default {}",
                        run.seq,
                        run.peak(),
                        default
                    );
                }
            }
        }
    }

    #[test]
    fn input_size_correlates_with_peak() {
        // the learnability assumption: corr(input, peak) must be strong
        let wf = sarek_workflow();
        let t = generate_workflow_trace(&wf, 11);
        let runs = t.runs_of("sarek/gatk4_baserecalibrator");
        let n = runs.len() as f64;
        let mx = runs.iter().map(|r| r.input_mib).sum::<f64>() / n;
        let my = runs.iter().map(|r| r.peak().0).sum::<f64>() / n;
        let mut cov = 0.0;
        let mut vx = 0.0;
        let mut vy = 0.0;
        for r in runs {
            let dx = r.input_mib - mx;
            let dy = r.peak().0 - my;
            cov += dx * dy;
            vx += dx * dx;
            vy += dy * dy;
        }
        let corr = cov / (vx.sqrt() * vy.sqrt());
        assert!(corr > 0.5, "corr={corr}");
    }

    #[test]
    fn upstream_types_appear_before_downstream_in_seq_order() {
        let wf = eager_workflow();
        let t = generate_workflow_trace(&wf, 3);
        let first_seq = |ty: &str| t.runs_of(ty).iter().map(|r| r.seq).min().unwrap();
        // fastqc (level 0) strictly before bwa_align (level 3+)
        assert!(first_seq("eager/fastqc") < first_seq("eager/bwa_align"));
    }

    #[test]
    fn wave_interleaving_spreads_types() {
        // within the first 2*n_types sequence slots, many distinct types
        let wf = sarek_workflow();
        let t = generate_workflow_trace(&wf, 5);
        let all = t.all_runs_ordered();
        let first: std::collections::HashSet<&str> =
            all[..40].iter().map(|r| r.task_type.as_str()).collect();
        assert!(first.len() > 10, "only {} types in first 40 runs", first.len());
    }

    #[test]
    fn runtime_equals_series_duration() {
        let wf = eager_workflow();
        let t = generate_workflow_trace(&wf, 9);
        for run in t.runs_of("eager/adapter_removal") {
            assert_eq!(run.runtime, run.series.duration());
        }
    }
}
