//! Structured event log for the workflow engine — the observability
//! surface a production SWMS integration would scrape (counters alone
//! hide *which* task retried and why).

use ksegments_core::units::MemMiB;

/// One engine event, in occurrence order.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineEvent {
    /// Task submitted with a predicted (peak) allocation.
    Submitted { task_type: String, seq: u64, requested: MemMiB },
    /// Resource manager could not place the request immediately.
    Queued { task_type: String, seq: u64, requested: MemMiB },
    /// Attempt failed by under-allocation at `time_s`.
    Failed {
        task_type: String,
        seq: u64,
        attempt: u32,
        time_s: f64,
        used: MemMiB,
        allocated: MemMiB,
    },
    /// Run completed (possibly after retries).
    Completed { task_type: String, seq: u64, attempts: u32 },
    /// Scheduler: attempt placed on `node` at simulated time `time_s`
    /// with its initial reservation ([`crate::sched`]).
    Placed { task_type: String, seq: u64, node: usize, time_s: f64, reserved: MemMiB },
    /// Scheduler: attempt OOM-killed at `time_s` (ground-truth usage
    /// exceeded the reservation); the task is requeued with an
    /// escalated allocation.
    OomKilled { task_type: String, seq: u64, attempt: u32, time_s: f64 },
    /// Scheduler: a segment-boundary grow request was denied by the
    /// node (memory contention, not a misprediction); the task is
    /// requeued with a full-peak reservation.
    GrowDenied { task_type: String, seq: u64, segment: usize, time_s: f64 },
    /// Scheduler (DAG mode): every parent of this task in workflow
    /// instance `instance` has completed, so the task is released to
    /// the resource manager at `time_s`. Roots are released when their
    /// instance arrives.
    Released { task_type: String, seq: u64, instance: u64, time_s: f64 },
    /// Scheduler (DAG mode): the last task of workflow instance
    /// `instance` completed at `time_s`; `makespan_s` is measured from
    /// the instance's arrival. `task_type()` reports the workflow
    /// name, `seq()` the instance ordinal.
    WorkflowDone { workflow: String, instance: u64, tasks: u32, time_s: f64, makespan_s: f64 },
    /// Scheduler: attempt killed because its node was lost; the task
    /// is requeued **blamelessly** (same allocation, same attempt
    /// number — the predictor is never told).
    NodeLost { task_type: String, seq: u64, attempt: u32, node: usize, time_s: f64 },
    /// Scheduler: attempt evicted to make room for a higher-priority
    /// task; requeued blamelessly like a node loss.
    Preempted { task_type: String, seq: u64, attempt: u32, node: usize, time_s: f64 },
    /// Scheduler: node `node` went down, killing `killed` resident
    /// attempts. `task_type()` reports `"cluster"`, `seq()` the node.
    NodeFailed { node: usize, killed: u32, time_s: f64 },
    /// Scheduler: node `node` came (back) up — a post-failure rejoin
    /// or an autoscaled node finishing provisioning.
    NodeJoined { node: usize, time_s: f64 },
    /// Scheduler: the autoscaler retired idle node `node`.
    NodeRetired { node: usize, time_s: f64 },
}

impl EngineEvent {
    pub fn task_type(&self) -> &str {
        match self {
            EngineEvent::Submitted { task_type, .. }
            | EngineEvent::Queued { task_type, .. }
            | EngineEvent::Failed { task_type, .. }
            | EngineEvent::Completed { task_type, .. }
            | EngineEvent::Placed { task_type, .. }
            | EngineEvent::OomKilled { task_type, .. }
            | EngineEvent::GrowDenied { task_type, .. }
            | EngineEvent::Released { task_type, .. }
            | EngineEvent::NodeLost { task_type, .. }
            | EngineEvent::Preempted { task_type, .. } => task_type,
            EngineEvent::WorkflowDone { workflow, .. } => workflow,
            EngineEvent::NodeFailed { .. }
            | EngineEvent::NodeJoined { .. }
            | EngineEvent::NodeRetired { .. } => "cluster",
        }
    }

    pub fn seq(&self) -> u64 {
        match self {
            EngineEvent::Submitted { seq, .. }
            | EngineEvent::Queued { seq, .. }
            | EngineEvent::Failed { seq, .. }
            | EngineEvent::Completed { seq, .. }
            | EngineEvent::Placed { seq, .. }
            | EngineEvent::OomKilled { seq, .. }
            | EngineEvent::GrowDenied { seq, .. }
            | EngineEvent::Released { seq, .. }
            | EngineEvent::NodeLost { seq, .. }
            | EngineEvent::Preempted { seq, .. } => *seq,
            EngineEvent::WorkflowDone { instance, .. } => *instance,
            EngineEvent::NodeFailed { node, .. }
            | EngineEvent::NodeJoined { node, .. }
            | EngineEvent::NodeRetired { node, .. } => *node as u64,
        }
    }
}

/// Append-only event log with query helpers.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    events: Vec<EngineEvent>,
    /// Cap to bound memory in long soaks (0 = unbounded). When hit, the
    /// oldest half is dropped (coarse ring semantics; counters in
    /// `EngineReport` stay exact).
    cap: usize,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    pub fn with_cap(cap: usize) -> EventLog {
        EventLog { events: Vec::new(), cap }
    }

    pub fn push(&mut self, ev: EngineEvent) {
        if self.cap > 0 && self.events.len() >= self.cap {
            self.events.drain(..self.cap / 2);
        }
        self.events.push(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &EngineEvent> {
        self.events.iter()
    }

    /// All failures of a task type, in order.
    pub fn failures_of(&self, task_type: &str) -> Vec<&EngineEvent> {
        self.events
            .iter()
            .filter(|e| matches!(e, EngineEvent::Failed { .. }) && e.task_type() == task_type)
            .collect()
    }

    /// Runs that needed more than one attempt.
    pub fn retried_runs(&self) -> Vec<(String, u64, u32)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                EngineEvent::Completed { task_type, seq, attempts } if *attempts > 1 => {
                    Some((task_type.clone(), *seq, *attempts))
                }
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn failed(ty: &str, seq: u64, attempt: u32) -> EngineEvent {
        EngineEvent::Failed {
            task_type: ty.into(),
            seq,
            attempt,
            time_s: 1.0,
            used: MemMiB(200.0),
            allocated: MemMiB(100.0),
        }
    }

    #[test]
    fn push_and_query() {
        let mut log = EventLog::new();
        log.push(EngineEvent::Submitted { task_type: "a".into(), seq: 0, requested: MemMiB(1.0) });
        log.push(failed("a", 0, 1));
        log.push(EngineEvent::Completed { task_type: "a".into(), seq: 0, attempts: 2 });
        log.push(EngineEvent::Completed { task_type: "b".into(), seq: 1, attempts: 1 });
        assert_eq!(log.len(), 4);
        assert_eq!(log.failures_of("a").len(), 1);
        assert!(log.failures_of("b").is_empty());
        assert_eq!(log.retried_runs(), vec![("a".to_string(), 0, 2)]);
    }

    #[test]
    fn accessors() {
        let e = failed("x", 7, 3);
        assert_eq!(e.task_type(), "x");
        assert_eq!(e.seq(), 7);
    }

    #[test]
    fn scheduler_event_accessors() {
        let placed = EngineEvent::Placed {
            task_type: "s".into(),
            seq: 9,
            node: 2,
            time_s: 4.0,
            reserved: MemMiB(512.0),
        };
        let oom =
            EngineEvent::OomKilled { task_type: "s".into(), seq: 9, attempt: 1, time_s: 8.0 };
        let denied =
            EngineEvent::GrowDenied { task_type: "s".into(), seq: 9, segment: 2, time_s: 6.0 };
        let released =
            EngineEvent::Released { task_type: "s".into(), seq: 9, instance: 3, time_s: 2.0 };
        for e in [&placed, &oom, &denied, &released] {
            assert_eq!(e.task_type(), "s");
            assert_eq!(e.seq(), 9);
        }
    }

    #[test]
    fn failure_domain_event_accessors() {
        let lost = EngineEvent::NodeLost {
            task_type: "s".into(),
            seq: 9,
            attempt: 2,
            node: 1,
            time_s: 5.0,
        };
        let evicted = EngineEvent::Preempted {
            task_type: "s".into(),
            seq: 9,
            attempt: 1,
            node: 0,
            time_s: 6.0,
        };
        for e in [&lost, &evicted] {
            assert_eq!(e.task_type(), "s");
            assert_eq!(e.seq(), 9);
        }
        let failed = EngineEvent::NodeFailed { node: 3, killed: 2, time_s: 7.0 };
        let joined = EngineEvent::NodeJoined { node: 3, time_s: 8.0 };
        let retired = EngineEvent::NodeRetired { node: 3, time_s: 9.0 };
        for e in [&failed, &joined, &retired] {
            assert_eq!(e.task_type(), "cluster");
            assert_eq!(e.seq(), 3);
        }
    }

    #[test]
    fn workflow_done_reports_workflow_and_instance() {
        let done = EngineEvent::WorkflowDone {
            workflow: "eager".into(),
            instance: 4,
            tasks: 18,
            time_s: 99.0,
            makespan_s: 42.0,
        };
        assert_eq!(done.task_type(), "eager");
        assert_eq!(done.seq(), 4);
    }

    #[test]
    fn cap_drops_oldest_half() {
        let mut log = EventLog::with_cap(4);
        for i in 0..6 {
            log.push(EngineEvent::Completed { task_type: "t".into(), seq: i, attempts: 1 });
        }
        assert!(log.len() <= 4 + 1);
        // oldest events gone
        assert!(log.iter().all(|e| e.seq() >= 2));
    }
}
