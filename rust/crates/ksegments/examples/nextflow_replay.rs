//! End-to-end Nextflow replay demo: ingest the checked-in fixture
//! trace directory, stream it through k-Segments with a warm-start
//! checkpoint, then feed the same stream to the cluster scheduler.
//!
//! ```sh
//! cargo run --release --example nextflow_replay
//! ```
//!
//! CLI equivalents:
//!
//! ```sh
//! ksegments ingest crates/ksegments/tests/fixtures/nextflow --out /tmp/nf.jsonl
//! ksegments replay --source /tmp/nf.jsonl --method ksegments-selective \
//!     --checkpoint-out /tmp/nf.ckpt
//! ksegments replay --source /tmp/nf.jsonl --method ksegments-selective \
//!     --checkpoint /tmp/nf.ckpt
//! ```

use std::path::Path;

use ksegments::ingest::{replay_source, NextflowDirSource, ReplayConfig, TraceSource};
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::MemoryPredictor;
use ksegments::sched::{schedule_stream, SchedConfig};

fn make() -> Box<dyn MemoryPredictor> {
    Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/nextflow");
    let mut src = NextflowDirSource::open(&dir)?;
    println!(
        "source: {} — {} completed runs ({} rows skipped)",
        src.origin(),
        src.n_rows(),
        src.skipped_rows()
    );
    for (ty, mem) in src.defaults() {
        println!("  default {ty:<8} {mem}");
    }

    // 1. Cold streaming replay (4 type-sharded workers), checkpoint out.
    let cfg = ReplayConfig::default();
    let cold = replay_source(&mut src, &make, &cfg, 4, None)?;
    println!(
        "\ncold replay [{}]: {} runs ({} warm-up), avg wastage {:.3} GB·s, avg retries {:.3}",
        cold.report.method,
        cold.runs_replayed,
        cold.runs_warmup,
        cold.report.avg_wastage_gbs(),
        cold.report.avg_retries()
    );
    let ckpt = std::env::temp_dir().join("nextflow_replay.ckpt.jsonl");
    cold.checkpoint.save(&ckpt)?;
    println!(
        "checkpoint: {} task types, {} runs seen -> {}",
        cold.checkpoint.n_types(),
        cold.checkpoint.total_seen(),
        ckpt.display()
    );

    // 2. Warm-start replay: every type is already trained, so nothing
    //    is burned on warm-up and every run scores.
    src.rewind()?;
    let warm = replay_source(&mut src, &make, &cfg, 4, Some(&cold.checkpoint))?;
    println!(
        "warm replay: {} runs ({} warm-up), avg wastage {:.3} GB·s",
        warm.runs_replayed,
        warm.runs_warmup,
        warm.report.avg_wastage_gbs()
    );

    // 3. Stream the same source through the discrete-event scheduler,
    //    warm-starting the predictor from the checkpoint.
    src.rewind()?;
    let mut predictor = make();
    cold.checkpoint.restore_into(predictor.as_mut());
    let (sched, _log) = schedule_stream(&mut src, predictor.as_mut(), &SchedConfig::default(), 64)?;
    println!("\nscheduled as a stream:\n{}", sched.summary());
    Ok(())
}
