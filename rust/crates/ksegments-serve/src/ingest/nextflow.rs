//! Nextflow trace ingestion: `trace.txt` TSV + per-task monitoring
//! sample CSVs, normalized into the crate's [`Trace`] model.
//!
//! ## Accepted layout
//!
//! ```text
//! <dir>/trace.txt          tab-separated, one header line + one row
//!                          per task execution (Nextflow `-with-trace`)
//! <dir>/samples/<id>.csv   optional per-task monitoring dump keyed by
//! <dir>/monitoring/<id>.csv  the row's task_id column (either subdir)
//! ```
//!
//! From `trace.txt` we read, by header name: the task type (`process`,
//! falling back to `name` with its ` (tag)` suffix stripped), `status`
//! (only `COMPLETED` rows become runs), `realtime` (duration syntax:
//! `350ms`, `2.5s`, `1m 30s`, `1h 2m`), `peak_rss` and the requested
//! `memory` (unit-suffixed via [`MemMiB::parse`]; `memory` becomes the
//! task type's developer default), and the input size (`input_size`,
//! else `rchar`, else `read_bytes`). Rows are ordered by the `submit`
//! (else `start`) column when **every** completed row has a numeric
//! value — epoch millis in Nextflow's raw mode — else the whole file
//! keeps its on-disk order (mixing timestamp and file-index keys would
//! missort the gap rows); the resulting rank is the run's global `seq`.
//!
//! A task with a monitoring CSV (`time_s,rss` rows, unit suffixes
//! allowed, uniform sampling assumed) gets its real usage series; a
//! task without one gets a flat single-sample series at `peak_rss`
//! over `realtime` — peak-faithful, so static baselines and wastage
//! accounting stay meaningful on plain `trace.txt`-only dumps.
//!
//! Real nf-core dumps are messy: durations come as `350ms`, `12.5s`
//! or `1m 30s`; optional cells (`peak_rss`, `memory`, the input-size
//! columns, `submit`) are `-` or empty for cached/virtual tasks. All
//! of these parse; what cannot be made sense of — a malformed number,
//! an unknown unit, or a row whose memory usage is unreconstructable
//! (`-` peak_rss **and** no monitoring CSV) — fails with the
//! `trace.txt` line number instead of being silently skipped or
//! panicking downstream.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use ksegments_core::trace::{TaskRun, Trace, UsageSeries};
use ksegments_core::units::{MemMiB, Seconds};

use super::TraceSource;

/// Minimum runtime / sampling interval floor (seconds): `0ms` rows
/// must still produce a valid [`UsageSeries`].
const MIN_INTERVAL_S: f64 = 1e-3;

/// Parse a Nextflow duration: whitespace-separated tokens of
/// `<number><unit>` with units `ms`, `s`, `m`, `h`, `d` (a bare number
/// is seconds). Examples: `"350ms"`, `"2.5s"`, `"1m 30s"`, `"1h 2m"`.
pub fn parse_duration_s(s: &str) -> Result<f64> {
    let t = s.trim();
    ensure!(!t.is_empty(), "empty duration");
    let mut total = 0.0f64;
    for tok in t.split_whitespace() {
        let split = tok
            .find(|c: char| c.is_ascii_alphabetic())
            .unwrap_or(tok.len());
        let (num, unit) = (&tok[..split], &tok[split..]);
        let v: f64 = num
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number in duration {s:?}"))?;
        ensure!(v.is_finite() && v >= 0.0, "negative or non-finite duration {s:?}");
        let secs = match unit.to_ascii_lowercase().as_str() {
            "" | "s" | "sec" => v,
            "ms" => v / 1e3,
            "m" | "min" => v * 60.0,
            "h" => v * 3600.0,
            "d" => v * 86400.0,
            other => bail!("unknown duration unit {other:?} in {s:?}"),
        };
        total += secs;
    }
    Ok(total)
}

/// One `trace.txt` row of interest, pending its usage series.
#[derive(Debug, Clone)]
struct IndexRow {
    task_id: String,
    task_type: String,
    input_mib: f64,
    runtime_s: f64,
    /// `None` when the cell was `-`/empty — fine as long as a
    /// monitoring CSV exists, a line-numbered error otherwise.
    peak_rss_mib: Option<f64>,
    /// 1-based `trace.txt` line, for errors raised after indexing.
    lineno: usize,
    seq: u64,
}

/// A [`TraceSource`] over a Nextflow trace directory.
///
/// `trace.txt` is indexed entirely at [`NextflowDirSource::open`] (it
/// is the small file); the per-task monitoring CSVs — the bulk of the
/// data — are read lazily, chunk by chunk, as the stream is consumed.
pub struct NextflowDirSource {
    dir: PathBuf,
    index: Vec<IndexRow>,
    defaults: Vec<(String, MemMiB)>,
    skipped: usize,
    pos: usize,
}

/// Is the field present (Nextflow writes `-` for not-available)?
fn present(field: &str) -> Option<String> {
    let t = field.trim();
    if t.is_empty() || t == "-" {
        None
    } else {
        Some(t.to_string())
    }
}

/// Extract column `c` of row `f`, treating `-`/empty as absent.
fn field(f: &[&str], c: Option<usize>) -> Option<String> {
    c.and_then(|i| f.get(i)).copied().and_then(present)
}

impl NextflowDirSource {
    /// Index `<dir>/trace.txt`; fails with row/line context on any
    /// malformed field.
    pub fn open(dir: &Path) -> Result<NextflowDirSource> {
        let path = dir.join("trace.txt");
        let r = BufReader::new(
            File::open(&path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut lines = r.lines();
        let header = lines
            .next()
            .transpose()?
            .context("empty trace.txt (missing header)")?;
        let cols: Vec<String> = header
            .trim_end_matches(['\r', '\n'])
            .split('\t')
            .map(|c| c.trim().to_string())
            .collect();
        let col = |name: &str| cols.iter().position(|c| c == name);
        let c_name = col("name");
        let c_process = col("process");
        ensure!(
            c_name.is_some() || c_process.is_some(),
            "trace.txt header has neither a name nor a process column: {header:?}"
        );
        let c_realtime = col("realtime")
            .or_else(|| col("duration"))
            .context("trace.txt header lacks a realtime/duration column")?;
        let c_status = col("status");
        let c_task_id = col("task_id");
        let c_peak = col("peak_rss");
        let c_memory = col("memory");
        let c_input = col("input_size").or_else(|| col("rchar")).or_else(|| col("read_bytes"));
        let c_order = col("submit").or_else(|| col("start"));

        // (order_key, file_idx, row): sorted into the arrival order.
        // order_key stays None when the submit/start field is missing
        // or non-numeric — mixing file indices with epoch timestamps
        // would sort those rows to the front, so any gap falls the
        // whole file back to file order.
        let mut rows: Vec<(Option<f64>, usize, IndexRow)> = Vec::new();
        let mut defaults: BTreeMap<String, MemMiB> = BTreeMap::new();
        let mut skipped = 0usize;
        for (i, line) in lines.enumerate() {
            let lineno = i + 2; // 1-based, after the header
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.trim_end_matches(['\r', '\n']).split('\t').collect();
            ensure!(
                f.len() == cols.len(),
                "trace.txt line {lineno}: expected {} tab-separated fields, got {}",
                cols.len(),
                f.len()
            );
            if let Some(status) = field(&f, c_status) {
                if status != "COMPLETED" {
                    skipped += 1;
                    continue;
                }
            }
            let task_type = match field(&f, c_process) {
                Some(p) => p,
                None => {
                    let name = field(&f, c_name)
                        .with_context(|| format!("trace.txt line {lineno}: empty name"))?;
                    // "ALIGN (sample_3)" -> "ALIGN"
                    name.split(" (").next().unwrap_or(&name).to_string()
                }
            };
            let runtime_s = {
                let raw = field(&f, Some(c_realtime))
                    .with_context(|| format!("trace.txt line {lineno}: missing realtime"))?;
                parse_duration_s(&raw)
                    .with_context(|| format!("trace.txt line {lineno}: realtime"))?
                    .max(MIN_INTERVAL_S)
            };
            let peak_rss_mib = match field(&f, c_peak) {
                Some(raw) => Some(
                    MemMiB::parse(&raw)
                        .map_err(|e| anyhow::anyhow!("trace.txt line {lineno}: peak_rss: {e}"))?
                        .0,
                ),
                None => None,
            };
            if let Some(raw) = field(&f, c_memory) {
                let mem = MemMiB::parse(&raw)
                    .map_err(|e| anyhow::anyhow!("trace.txt line {lineno}: memory: {e}"))?;
                // requested memory is the developer default; keep the
                // largest request seen for the type
                defaults
                    .entry(task_type.clone())
                    .and_modify(|m| *m = m.max(mem))
                    .or_insert(mem);
            }
            let input_mib = match field(&f, c_input) {
                Some(raw) => {
                    MemMiB::parse(&raw)
                        .map_err(|e| anyhow::anyhow!("trace.txt line {lineno}: input size: {e}"))?
                        .0
                }
                None => 0.0,
            };
            let task_id = field(&f, c_task_id).unwrap_or_else(|| format!("row{lineno}"));
            let order_key = field(&f, c_order)
                .and_then(|raw| raw.parse::<f64>().ok())
                .filter(|k| k.is_finite());
            rows.push((
                order_key,
                i,
                IndexRow {
                    task_id,
                    task_type,
                    input_mib,
                    runtime_s,
                    peak_rss_mib,
                    lineno,
                    seq: 0,
                },
            ));
        }
        if rows.iter().all(|(k, _, _)| k.is_some()) {
            rows.sort_by(|a, b| {
                let (ka, kb) = (a.0.expect("checked"), b.0.expect("checked"));
                ka.total_cmp(&kb).then(a.1.cmp(&b.1))
            });
        } // else: incomparable keys — keep file order
        let index = rows
            .into_iter()
            .enumerate()
            .map(|(seq, (_, _, mut row))| {
                row.seq = seq as u64;
                row
            })
            .collect();
        Ok(NextflowDirSource {
            dir: dir.to_path_buf(),
            index,
            defaults: defaults.into_iter().collect(),
            skipped,
            pos: 0,
        })
    }

    /// Completed rows indexed (== runs the stream will yield).
    pub fn n_rows(&self) -> usize {
        self.index.len()
    }

    /// Rows skipped because their status was not `COMPLETED`.
    pub fn skipped_rows(&self) -> usize {
        self.skipped
    }

    /// Load a row's usage series: its monitoring CSV when one exists,
    /// else a flat single-sample series at `peak_rss` over `realtime`.
    /// A row with neither (`-` peak_rss, no CSV) has no memory
    /// information at all — that is a line-numbered error, not a
    /// silent zero-usage run.
    fn series_for(&self, row: &IndexRow) -> Result<UsageSeries> {
        for sub in ["samples", "monitoring"] {
            let path = self.dir.join(sub).join(format!("{}.csv", row.task_id));
            if path.is_file() {
                return read_samples_csv(&path, row.runtime_s);
            }
        }
        let peak = row.peak_rss_mib.with_context(|| {
            format!(
                "trace.txt line {}: peak_rss is missing and task {} has no \
                 monitoring CSV — the row carries no memory information",
                row.lineno, row.task_id
            )
        })?;
        Ok(UsageSeries::new(row.runtime_s.max(MIN_INTERVAL_S), vec![peak]))
    }
}

/// Parse one monitoring sample CSV: a header line, then `time,rss`
/// rows (times in seconds, ascending and uniformly spaced; rss with an
/// optional unit suffix). The sampling interval is inferred from the
/// time column; a single-row file covers the whole runtime.
fn read_samples_csv(path: &Path, runtime_s: f64) -> Result<UsageSeries> {
    let r = BufReader::new(
        File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut times: Vec<f64> = Vec::new();
    let mut samples: Vec<f64> = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let lineno = i + 1;
        let line = line?;
        let t = line.trim();
        if t.is_empty() || i == 0 {
            // header (required) — tolerate any two-column header text
            if i == 0 {
                ensure!(
                    t.contains(','),
                    "{} line 1: expected a time,rss header",
                    path.display()
                );
            }
            continue;
        }
        let (ts, ms) = t
            .split_once(',')
            .with_context(|| format!("{} line {lineno}: expected time,rss", path.display()))?;
        let time: f64 = ts
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("{} line {lineno}: bad time {ts:?}", path.display()))?;
        ensure!(
            time.is_finite() && times.last().is_none_or(|prev| time > *prev),
            "{} line {lineno}: times must be finite and strictly increasing",
            path.display()
        );
        let mem = MemMiB::parse(ms)
            .map_err(|e| anyhow::anyhow!("{} line {lineno}: rss: {e}", path.display()))?;
        times.push(time);
        samples.push(mem.0);
    }
    ensure!(!samples.is_empty(), "{}: no sample rows", path.display());
    let interval = if times.len() >= 2 {
        (times[times.len() - 1] - times[0]) / (times.len() - 1) as f64
    } else {
        runtime_s
    };
    Ok(UsageSeries::new(interval.max(MIN_INTERVAL_S), samples))
}

impl TraceSource for NextflowDirSource {
    fn origin(&self) -> String {
        self.dir.display().to_string()
    }

    fn defaults(&self) -> Vec<(String, MemMiB)> {
        self.defaults.clone()
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<TaskRun>> {
        let end = (self.pos + max.max(1)).min(self.index.len());
        let mut out = Vec::with_capacity(end - self.pos);
        for row in &self.index[self.pos..end] {
            let series = self.series_for(row).with_context(|| {
                format!("loading monitoring series for task {}", row.task_id)
            })?;
            out.push(TaskRun {
                task_type: row.task_type.clone(),
                input_mib: row.input_mib,
                runtime: Seconds(row.runtime_s),
                series,
                seq: row.seq,
            });
        }
        self.pos = end;
        Ok(out)
    }

    fn rewind(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }
}

/// Parse a whole Nextflow trace directory into a materialized
/// [`Trace`] — `ksegments ingest`'s core, and the batch-surface bridge.
pub fn read_nextflow_dir(dir: &Path) -> Result<Trace> {
    let mut src = NextflowDirSource::open(dir)?;
    super::materialize(&mut src)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_syntax() {
        assert_eq!(parse_duration_s("42").unwrap(), 42.0);
        assert_eq!(parse_duration_s("350ms").unwrap(), 0.35);
        assert_eq!(parse_duration_s("2.5s").unwrap(), 2.5);
        assert_eq!(parse_duration_s("1m 30s").unwrap(), 90.0);
        assert_eq!(parse_duration_s("1h 2m").unwrap(), 3720.0);
        assert_eq!(parse_duration_s("1d").unwrap(), 86400.0);
        assert_eq!(parse_duration_s(" 3s ").unwrap(), 3.0);
    }

    #[test]
    fn duration_rejects_garbage() {
        for bad in ["", "  ", "abc", "-1s", "1parsec", "1h3x"] {
            assert!(parse_duration_s(bad).is_err(), "{bad:?} should not parse");
        }
    }

    fn write_dir(name: &str, trace_txt: &str, samples: &[(&str, &str)]) -> PathBuf {
        let dir = std::env::temp_dir().join("ksegments_test_nextflow").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("samples")).unwrap();
        std::fs::write(dir.join("trace.txt"), trace_txt).unwrap();
        for (id, body) in samples {
            std::fs::write(dir.join("samples").join(format!("{id}.csv")), body).unwrap();
        }
        dir
    }

    const HEADER: &str =
        "task_id\thash\tprocess\ttag\tname\tstatus\texit\tsubmit\trealtime\tpeak_rss\tmemory\trchar";

    fn row(
        id: u32,
        process: &str,
        status: &str,
        submit: u64,
        realtime: &str,
        peak: &str,
        mem: &str,
        rchar: &str,
    ) -> String {
        format!(
            "{id}\tha/sh{id}\t{process}\ts{id}\t{process} (s{id})\t{status}\t0\t{submit}\t\
             {realtime}\t{peak}\t{mem}\t{rchar}"
        )
    }

    #[test]
    fn parses_trace_txt_with_samples_and_fallback() {
        let trace_txt = format!(
            "{HEADER}\n{}\n{}\n{}\n{}\n",
            row(1, "ALIGN", "COMPLETED", 1000, "20s", "400 MB", "2 GB", "100 MB"),
            row(2, "QUANT", "COMPLETED", 2000, "1m 10s", "1.5 GB", "4 GB", "250 MB"),
            row(3, "ALIGN", "FAILED", 2500, "5s", "100 MB", "2 GB", "50 MB"),
            row(4, "ALIGN", "COMPLETED", 3000, "22s", "450 MB", "2 GB", "120 MB"),
        );
        let dir = write_dir(
            "basic",
            &trace_txt,
            &[("1", "time_s,rss\n0,100 MB\n2,250 MB\n4,400 MB\n")],
        );
        let mut src = NextflowDirSource::open(&dir).unwrap();
        assert_eq!(src.n_rows(), 3);
        assert_eq!(src.skipped_rows(), 1);
        // defaults from the requested-memory column
        let defaults = src.defaults();
        assert_eq!(defaults.len(), 2);
        assert_eq!(defaults[0].0, "ALIGN");
        assert!((defaults[0].1 .0 - MemMiB::parse("2 GB").unwrap().0).abs() < 1e-9);
        let runs = src.next_chunk(100).unwrap();
        assert_eq!(runs.len(), 3);
        // arrival order by submit; seq assigned by rank
        assert_eq!(runs[0].task_type, "ALIGN");
        assert_eq!(runs[1].task_type, "QUANT");
        assert_eq!(runs[2].task_type, "ALIGN");
        assert_eq!(runs.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
        // task 1 has a real series (interval inferred = 2 s)
        assert_eq!(runs[0].series.len(), 3);
        assert_eq!(runs[0].series.interval().0, 2.0);
        assert!((runs[0].peak().0 - MemMiB::parse("400 MB").unwrap().0).abs() < 1e-9);
        // task 4 falls back to a flat peak_rss series over realtime
        assert_eq!(runs[2].series.len(), 1);
        assert_eq!(runs[2].series.interval().0, 22.0);
        assert!((runs[2].peak().0 - MemMiB::parse("450 MB").unwrap().0).abs() < 1e-9);
        // runtimes parsed from duration syntax
        assert_eq!(runs[1].runtime, Seconds(70.0));
        // input sizes from rchar
        assert!((runs[0].input_mib - MemMiB::parse("100 MB").unwrap().0).abs() < 1e-9);
    }

    #[test]
    fn read_dir_materializes_sorted_trace() {
        let trace_txt = format!(
            "{HEADER}\n{}\n{}\n",
            // out-of-order submit columns: row order must not matter
            row(2, "B", "COMPLETED", 5000, "4s", "100 MB", "1 GB", "10 MB"),
            row(1, "A", "COMPLETED", 1000, "4s", "200 MB", "1 GB", "10 MB"),
        );
        let dir = write_dir("sorted", &trace_txt, &[]);
        let trace = read_nextflow_dir(&dir).unwrap();
        assert_eq!(trace.n_runs(), 2);
        assert_eq!(trace.runs_of("A")[0].seq, 0);
        assert_eq!(trace.runs_of("B")[0].seq, 1);
    }

    /// Regression: a row with a missing submit timestamp must not sort
    /// to the front of epoch-milli rows (file-index keys are on a
    /// different scale) — one gap falls the whole file back to file
    /// order.
    #[test]
    fn missing_submit_falls_back_to_file_order() {
        let trace_txt = format!(
            "{HEADER}\n{}\n{}\n{}\n",
            row(1, "A", "COMPLETED", 1700000002000, "4s", "100 MB", "1 GB", "10 MB"),
            // '-' submit: under key-mixing this row would win seq 0
            "2\tha/sh2\tB\ts2\tB (s2)\tCOMPLETED\t0\t-\t4s\t100 MB\t1 GB\t10 MB",
            row(3, "C", "COMPLETED", 1700000001000, "4s", "100 MB", "1 GB", "10 MB"),
        );
        let dir = write_dir("mixedsubmit", &trace_txt, &[]);
        let mut src = NextflowDirSource::open(&dir).unwrap();
        let runs = src.next_chunk(10).unwrap();
        let order: Vec<&str> = runs.iter().map(|r| r.task_type.as_str()).collect();
        assert_eq!(order, vec!["A", "B", "C"], "file order must be kept");
        // fully numeric submits do sort by timestamp (C before A)
        let trace_txt = format!(
            "{HEADER}\n{}\n{}\n",
            row(1, "A", "COMPLETED", 1700000002000, "4s", "100 MB", "1 GB", "10 MB"),
            row(3, "C", "COMPLETED", 1700000001000, "4s", "100 MB", "1 GB", "10 MB"),
        );
        let dir = write_dir("numericsubmit", &trace_txt, &[]);
        let mut src = NextflowDirSource::open(&dir).unwrap();
        let runs = src.next_chunk(10).unwrap();
        let order: Vec<&str> = runs.iter().map(|r| r.task_type.as_str()).collect();
        assert_eq!(order, vec!["C", "A"]);
    }

    #[test]
    fn malformed_rows_report_their_line() {
        let trace_txt = format!(
            "{HEADER}\n{}\n{}\n",
            row(1, "A", "COMPLETED", 1000, "4s", "100 MB", "1 GB", "10 MB"),
            row(2, "A", "COMPLETED", 2000, "4s", "100 XB", "1 GB", "10 MB"),
        );
        let dir = write_dir("badmem", &trace_txt, &[]);
        let err = NextflowDirSource::open(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "{err:#}");

        let dir = write_dir("badfields", &format!("{HEADER}\na\tb\n"), &[]);
        let err = NextflowDirSource::open(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    /// The nf-core reality pass: `ms` durations, bare-second decimals
    /// and `-` optional cells all parse through the full pipeline.
    #[test]
    fn real_nextflow_forms_parse_end_to_end() {
        let trace_txt = format!(
            "{HEADER}\n{}\n{}\n{}\n",
            row(1, "A", "COMPLETED", 1000, "750ms", "100 MB", "1 GB", "10 MB"),
            row(2, "A", "COMPLETED", 2000, "12.5s", "120 MB", "1 GB", "12 MB"),
            // '-' in every optional column; the samples CSV supplies
            // the usage series
            "3\tha/sh3\tB\ts3\tB (s3)\tCOMPLETED\t0\t3000\t1m 30s\t-\t-\t-",
        );
        let dir = write_dir(
            "nfforms",
            &trace_txt,
            &[("3", "time_s,rss\n0,600 MB\n45,900 MB\n")],
        );
        let mut src = NextflowDirSource::open(&dir).unwrap();
        let runs = src.next_chunk(10).unwrap();
        assert_eq!(runs.len(), 3);
        assert!((runs[0].runtime.0 - 0.75).abs() < 1e-9, "750ms realtime");
        assert!((runs[1].runtime.0 - 12.5).abs() < 1e-9, "12.5s realtime");
        assert_eq!(runs[2].runtime, Seconds(90.0));
        assert_eq!(runs[2].series.len(), 2, "series from the CSV despite '-' peak_rss");
        assert!((runs[2].peak().0 - MemMiB::parse("900 MB").unwrap().0).abs() < 1e-9);
        assert_eq!(runs[2].input_mib, 0.0, "'-' input defaults to 0");
        // '-' memory contributes no default for B
        assert!(src.defaults().iter().all(|(ty, _)| ty != "B"));
    }

    /// A row with neither a peak_rss value nor a monitoring CSV has no
    /// memory information — that must be a line-numbered error, not a
    /// silent zero-usage run.
    #[test]
    fn missing_peak_without_csv_is_a_line_numbered_error() {
        let trace_txt = format!(
            "{HEADER}\n{}\n{}\n",
            row(1, "A", "COMPLETED", 1000, "4s", "100 MB", "1 GB", "10 MB"),
            "2\tha/sh2\tA\ts2\tA (s2)\tCOMPLETED\t0\t2000\t4s\t-\t1 GB\t10 MB",
        );
        let dir = write_dir("nopeak", &trace_txt, &[]);
        let mut src = NextflowDirSource::open(&dir).unwrap();
        let err = src.next_chunk(10).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 3"), "{msg:?}");
        assert!(msg.contains("peak_rss"), "{msg:?}");
    }

    /// `-` realtime on a COMPLETED row is unrecoverable and must carry
    /// its line number too.
    #[test]
    fn missing_realtime_is_a_line_numbered_error() {
        let trace_txt = format!(
            "{HEADER}\n{}\n",
            "2\tha/sh2\tA\ts2\tA (s2)\tCOMPLETED\t0\t2000\t-\t100 MB\t1 GB\t10 MB",
        );
        let dir = write_dir("nort", &trace_txt, &[]);
        let err = NextflowDirSource::open(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg:?}");
        assert!(msg.contains("realtime"), "{msg:?}");
    }

    #[test]
    fn malformed_sample_csv_reports_file_and_line() {
        let trace_txt = format!(
            "{HEADER}\n{}\n",
            row(1, "A", "COMPLETED", 1000, "4s", "100 MB", "1 GB", "10 MB"),
        );
        let dir = write_dir("badcsv", &trace_txt, &[("1", "time_s,rss\n0,100 MB\n2,garbage\n")]);
        let mut src = NextflowDirSource::open(&dir).unwrap();
        let err = src.next_chunk(10).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 3"), "{msg:?}");
        assert!(msg.contains("task 1"), "{msg:?}");
    }

    #[test]
    fn missing_trace_txt_errors() {
        let dir = std::env::temp_dir().join("ksegments_test_nextflow").join("empty");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(NextflowDirSource::open(&dir).is_err());
    }
}
