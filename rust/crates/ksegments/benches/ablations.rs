//! `cargo bench --bench ablations` — the design-choice ablation suite
//! (DESIGN.md §7, EXPERIMENTS.md §Ablations): error offsets, retry
//! factor, history window, LR offset strategies, fixed-vs-adaptive k,
//! the predictor-zoo head-to-head, and the ensemble's RAQ weight α.

use ksegments::bench_harness::ablation::run_all;
use ksegments::bench_harness::time_once;

fn main() {
    let workers = ksegments::sim::default_workers();
    let (tables, _dt) = time_once(
        &format!("ablation suite (seed 42, 50% training, workers={workers})"),
        || run_all(42, workers),
    );
    println!("\n{tables}");
}
