//! Memory predictors: the k-Segments method and every baseline from
//! the paper's evaluation (§IV-C).
//!
//! | Implementation | Paper baseline |
//! |---|---|
//! | [`default_config::DefaultConfigPredictor`] | workflow developers' defaults (sanity baseline) |
//! | [`ppm::PpmPredictor`] (`FailurePolicy::NodeMax`) | Tovar et al. PPM |
//! | [`ppm::PpmPredictor`] (`FailurePolicy::Double`) | PPM Improved (the paper's extension) |
//! | [`lr_witt::LrWittPredictor`] | Witt et al. online LR (offsets: mean±σ / mean− / max) |
//! | [`ksegments::KSegmentsPredictor`] | the paper's k-Segments (Selective / Partial retry) |
//! | [`ensemble::EnsemblePredictor`] | Sizey-style scored ensemble of static sub-models (arXiv 2407.16353) |
//! | [`dynseg::DynSegPredictor`] | KS+-style data-driven dynamic segmentation (arXiv 2408.12290) |
//! | [`condor::CondorTriple`] | HTCondor `3 * MemoryUsage` retry heuristic (production baseline) |
//!
//! All predictors implement [`MemoryPredictor`]: an **online** contract
//! — `predict` before each execution, `on_failure` per failed attempt,
//! `observe` after each successful completion.

pub mod adaptive_k;
pub mod condor;
pub mod default_config;
pub mod dynseg;
pub mod ensemble;
pub mod history;
pub mod ksegments;
pub mod lr_witt;
pub mod ppm;
pub mod roster;

use crate::ml::step_fn::StepFunction;
use crate::trace::TaskRun;
use crate::units::MemMiB;

/// Paper §IV-A: minimum allocation when a model predicts ≤ 0 —
/// **100 MB** (decimal, the unit the paper quotes), which is
/// ≈ 95.37 MiB, not 100 MiB.
pub const MIN_ALLOC: MemMiB = MemMiB::from_mb(100.0);

/// [`MIN_ALLOC`] as raw MiB, for clamping in f64 arithmetic.
pub const MIN_ALLOC_MIB: f64 = MIN_ALLOC.0;

/// A memory allocation for one task attempt: either a single static
/// value for the whole runtime (all baselines) or the k-Segments step
/// function over time.
#[derive(Debug, Clone, PartialEq)]
pub enum Allocation {
    Static(MemMiB),
    Dynamic(StepFunction),
}

impl Allocation {
    /// Allocated MiB at time `t` into the attempt.
    pub fn value_at(&self, t: f64) -> f64 {
        match self {
            Allocation::Static(m) => m.0,
            Allocation::Dynamic(f) => f.value_at(t),
        }
    }

    /// Peak allocation over the attempt (what the resource manager
    /// must be able to admit).
    pub fn max_value(&self) -> f64 {
        match self {
            Allocation::Static(m) => m.0,
            Allocation::Dynamic(f) => f.max_value(),
        }
    }

    /// Segment index active at `t` (static allocations are one segment).
    pub fn segment_at(&self, t: f64) -> usize {
        match self {
            Allocation::Static(_) => 0,
            Allocation::Dynamic(f) => f.segment_at(t),
        }
    }

    pub fn is_dynamic(&self) -> bool {
        matches!(self, Allocation::Dynamic(_))
    }
}

/// Why an attempt was killed. Only [`FailureCause::Oom`] is the
/// predictor's fault; the other causes are cluster adversity and must
/// NOT escalate the estimate (the blameless-requeue rule — see
/// DESIGN.md §11). The scheduler enforces this by construction: it
/// calls [`MemoryPredictor::on_failure`] only for `Oom` kills, and the
/// cause rides along in [`FailureInfo`] so any custom harness can do
/// the same.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FailureCause {
    /// Ground-truth usage exceeded the allocation — a genuine
    /// underprediction the retry must correct.
    #[default]
    Oom,
    /// The node hosting the attempt was lost; the allocation was fine.
    NodeLost,
    /// Evicted to make room for a higher-priority task.
    Preempted,
}

impl FailureCause {
    /// True for causes that are not the predictor's fault: the retry
    /// keeps the same allocation and attempt number.
    pub fn is_blameless(self) -> bool {
        !matches!(self, FailureCause::Oom)
    }

    pub fn name(self) -> &'static str {
        match self {
            FailureCause::Oom => "oom",
            FailureCause::NodeLost => "node-lost",
            FailureCause::Preempted => "preempted",
        }
    }
}

/// What the simulator reports when an attempt is killed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureInfo {
    /// Time into the attempt at which the kill landed (for OOM: the
    /// instant `used > allocated`).
    pub time_s: f64,
    /// Usage at the kill instant (MiB).
    pub used_mib: f64,
    /// 1-based index of the failed attempt.
    pub attempt: u32,
    /// Why the attempt died.
    pub cause: FailureCause,
}

impl FailureInfo {
    /// A genuine under-allocation failure — the only cause for which
    /// the scheduler invokes `on_failure`.
    pub fn oom(time_s: f64, used_mib: f64, attempt: u32) -> FailureInfo {
        FailureInfo { time_s, used_mib, attempt, cause: FailureCause::Oom }
    }
}

/// The online predictor contract shared by the paper's method and all
/// baselines.
///
/// # Example
///
/// ```
/// use ksegments::predictors::default_config::DefaultConfigPredictor;
/// use ksegments::predictors::{Allocation, MemoryPredictor};
/// use ksegments::units::MemMiB;
///
/// let mut p = DefaultConfigPredictor::new();
/// p.prime("wf/align", MemMiB(2048.0));
/// assert_eq!(p.predict("wf/align", 100.0), Allocation::Static(MemMiB(2048.0)));
/// ```
pub trait MemoryPredictor: Send {
    /// Display name used in reports ("k-Segments Selective", "PPM", ...).
    fn name(&self) -> String;

    /// Register a task type's developer-default allocation — returned
    /// whenever the model has no history yet (the paper's online
    /// setting: unknown tasks fall back to user defaults).
    fn prime(&mut self, task_type: &str, default: MemMiB);

    /// Allocation for the next execution of `task_type` with the given
    /// total input size.
    fn predict(&mut self, task_type: &str, input_mib: f64) -> Allocation;

    /// The previous attempt failed (under-allocation at `info`);
    /// produce the allocation for the retry. The scheduler only calls
    /// this for [`FailureCause::Oom`] — blameless kills (node loss,
    /// preemption) requeue with the allocation unchanged.
    fn on_failure(
        &mut self,
        task_type: &str,
        input_mib: f64,
        failed: &Allocation,
        info: &FailureInfo,
    ) -> Allocation;

    /// A successful execution completed; fold it into the model.
    fn observe(&mut self, run: &TaskRun);

    /// Introspect the current fit for `task_type` — which sub-model
    /// is winning, its candidate scores, change points and offset —
    /// for the provenance log (DESIGN.md §12). Purely observational:
    /// implementations must not change what subsequent [`predict`]
    /// calls return (fits may be computed and cached, but the cache
    /// must be deterministically idempotent). Models with nothing to
    /// report keep the default `None`.
    ///
    /// [`predict`]: MemoryPredictor::predict
    fn decision(&mut self, _task_type: &str) -> Option<crate::telemetry::DecisionDetail> {
        None
    }
}

impl MemoryPredictor for Box<dyn MemoryPredictor> {
    fn name(&self) -> String {
        (**self).name()
    }
    fn prime(&mut self, task_type: &str, default: MemMiB) {
        (**self).prime(task_type, default)
    }
    fn predict(&mut self, task_type: &str, input_mib: f64) -> Allocation {
        (**self).predict(task_type, input_mib)
    }
    fn on_failure(
        &mut self,
        task_type: &str,
        input_mib: f64,
        failed: &Allocation,
        info: &FailureInfo,
    ) -> Allocation {
        (**self).on_failure(task_type, input_mib, failed, info)
    }
    fn observe(&mut self, run: &TaskRun) {
        (**self).observe(run)
    }
    fn decision(&mut self, task_type: &str) -> Option<crate::telemetry::DecisionDetail> {
        (**self).decision(task_type)
    }
}

/// Shared helper: the developer-default fallback map.
#[derive(Debug, Clone, Default)]
pub struct Defaults {
    map: std::collections::BTreeMap<String, MemMiB>,
}

impl Defaults {
    pub fn set(&mut self, task_type: &str, mem: MemMiB) {
        self.map.insert(task_type.to_string(), mem);
    }

    /// Default for a type; falls back to a conservative 8 GiB if the
    /// workflow did not configure one.
    pub fn get(&self, task_type: &str) -> MemMiB {
        self.map
            .get(task_type)
            .copied()
            .unwrap_or(MemMiB::from_gib(8.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Seconds;

    #[test]
    fn static_allocation_accessors() {
        let a = Allocation::Static(MemMiB(512.0));
        assert_eq!(a.value_at(0.0), 512.0);
        assert_eq!(a.value_at(1e9), 512.0);
        assert_eq!(a.max_value(), 512.0);
        assert_eq!(a.segment_at(55.0), 0);
        assert!(!a.is_dynamic());
    }

    #[test]
    fn dynamic_allocation_accessors() {
        let f = StepFunction::monotone_clamped(
            Seconds(40.0),
            vec![100.0, 200.0, 300.0, 400.0],
            MemMiB(100.0),
            MemMiB(1e6),
        );
        let a = Allocation::Dynamic(f);
        assert_eq!(a.value_at(5.0), 100.0);
        assert_eq!(a.value_at(35.0), 400.0);
        assert_eq!(a.max_value(), 400.0);
        assert_eq!(a.segment_at(15.0), 1);
        assert!(a.is_dynamic());
    }

    #[test]
    fn min_alloc_floor_is_100_decimal_megabytes() {
        // Regression: the floor used to be hard-coded as 100.0 MiB; the
        // paper's §IV-A floor is 100 MB = 100e6 bytes ≈ 95.37 MiB.
        assert_eq!(MIN_ALLOC, MemMiB::from_mb(100.0));
        assert!((MIN_ALLOC_MIB - 95.367431640625).abs() < 1e-9);
        assert!(MIN_ALLOC_MIB < 100.0);
        assert!((MIN_ALLOC.as_mb() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn defaults_fallback() {
        let mut d = Defaults::default();
        d.set("a", MemMiB(1000.0));
        assert_eq!(d.get("a"), MemMiB(1000.0));
        assert_eq!(d.get("unknown"), MemMiB::from_gib(8.0));
    }
}
