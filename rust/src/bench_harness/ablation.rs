//! Ablation studies for the design choices DESIGN.md calls out
//! (§IV-E discussion + §V future work):
//!
//! * the historical-error **offsets** (§III-B) — the paper's
//!   "avoid underpredictions" mechanism, on vs off;
//! * the **retry factor** l (paper default 2);
//! * the sliding **history window** feeding the fit;
//! * Witt et al.'s three **LR offset strategies** (mean±σ / mean− / max);
//! * fixed k = 4 vs the Fig. 8 best fixed k vs **adaptive per-task k**
//!   (our implementation of the paper's §V proposal).
//!
//! Exposed through `ksegments ablate` and `cargo bench --bench
//! ablations`; results recorded in EXPERIMENTS.md §Ablations.

use crate::bench_harness::figures::{evaluate_method, paper_traces};
use crate::predictors::adaptive_k::AdaptiveKPredictor;
use crate::predictors::ksegments::{KSegmentsConfig, KSegmentsPredictor, RetryStrategy};
use crate::predictors::lr_witt::{LrWittPredictor, OffsetStrategy};
use crate::predictors::MemoryPredictor;
use crate::units::MemMiB;

/// One ablation row: configuration label → (avg wastage GB·s, avg retries).
pub type AblationRow = (String, f64, f64);

fn run_one(mk: &dyn Fn() -> Box<dyn MemoryPredictor>, seed: u64, frac: f64) -> (f64, f64) {
    let traces = paper_traces(seed);
    let rep = evaluate_method(mk, &traces, frac);
    (rep.avg_wastage_gbs(), rep.avg_retries())
}

fn kseg_with(cfg: KSegmentsConfig, strategy: RetryStrategy) -> Box<dyn MemoryPredictor> {
    Box::new(KSegmentsPredictor::with_fitter(
        Box::new(crate::ml::fitter::NativeFitter),
        cfg,
        strategy,
    ))
}

/// Offsets on/off (both retry strategies).
pub fn ablate_offsets(seed: u64, frac: f64) -> Vec<AblationRow> {
    let mut rows = Vec::new();
    for strategy in [RetryStrategy::Selective, RetryStrategy::Partial] {
        for use_offsets in [true, false] {
            let cfg = KSegmentsConfig { use_offsets, ..KSegmentsConfig::default() };
            let (w, r) = run_one(&|| kseg_with(cfg.clone(), strategy), seed, frac);
            rows.push((
                format!(
                    "{} / offsets {}",
                    strategy.label(),
                    if use_offsets { "ON " } else { "OFF" }
                ),
                w,
                r,
            ));
        }
    }
    rows
}

/// Retry factor l sweep (paper default l = 2).
pub fn ablate_retry_factor(seed: u64, frac: f64, ls: &[f64]) -> Vec<AblationRow> {
    ls.iter()
        .map(|&l| {
            let cfg = KSegmentsConfig { retry_factor: l, ..KSegmentsConfig::default() };
            let (w, r) = run_one(&|| kseg_with(cfg.clone(), RetryStrategy::Selective), seed, frac);
            (format!("l = {l:.2}"), w, r)
        })
        .collect()
}

/// History window sweep (paper's online setting keeps all history; our
/// artifact pads to 64 — how much does the window matter?).
pub fn ablate_history_window(seed: u64, frac: f64, windows: &[usize]) -> Vec<AblationRow> {
    windows
        .iter()
        .map(|&n_hist| {
            let cfg = KSegmentsConfig { n_hist, ..KSegmentsConfig::default() };
            let (w, r) = run_one(&|| kseg_with(cfg.clone(), RetryStrategy::Selective), seed, frac);
            (format!("n_hist = {n_hist}"), w, r)
        })
        .collect()
}

/// Witt et al.'s offset strategies head-to-head.
pub fn ablate_lr_offsets(seed: u64, frac: f64) -> Vec<AblationRow> {
    [
        OffsetStrategy::MeanPlusStd,
        OffsetStrategy::MeanNeg,
        OffsetStrategy::MaxUnder,
    ]
    .into_iter()
    .map(|s| {
        let (w, r) = run_one(
            &|| Box::new(LrWittPredictor::new(s, MemMiB::from_gib(128.0))),
            seed,
            frac,
        );
        (format!("LR offset {}", s.label()), w, r)
    })
    .collect()
}

/// Fixed k vs adaptive per-task k (§V future work).
pub fn ablate_adaptive_k(seed: u64, frac: f64) -> Vec<AblationRow> {
    let mut rows = Vec::new();
    for k in [1usize, 4, 8, 13] {
        let cfg = KSegmentsConfig { k, ..KSegmentsConfig::default() };
        let (w, r) = run_one(&|| kseg_with(cfg.clone(), RetryStrategy::Selective), seed, frac);
        rows.push((format!("fixed k = {k}"), w, r));
    }
    let (w, r) = run_one(
        &|| Box::new(AdaptiveKPredictor::native(RetryStrategy::Selective)),
        seed,
        frac,
    );
    rows.push(("adaptive per-task k".to_string(), w, r));
    rows
}

/// Render rows as a markdown table.
pub fn render_ablation(title: &str, rows: &[AblationRow]) -> String {
    let mut out = format!("## Ablation — {title}\n\n| configuration | avg wastage (GB·s) | avg retries |\n|---|---|---|\n");
    for (label, w, r) in rows {
        out.push_str(&format!("| {label} | {w:.3} | {r:.3} |\n"));
    }
    out
}

/// All ablations at the paper's mid setting (50 % training).
pub fn run_all(seed: u64) -> String {
    let frac = 0.5;
    let mut out = String::new();
    out.push_str(&render_ablation("error offsets (§III-B)", &ablate_offsets(seed, frac)));
    out.push('\n');
    out.push_str(&render_ablation(
        "retry factor l (§III-D)",
        &ablate_retry_factor(seed, frac, &[1.25, 1.5, 2.0, 3.0]),
    ));
    out.push('\n');
    out.push_str(&render_ablation(
        "history window",
        &ablate_history_window(seed, frac, &[8, 16, 32, 64]),
    ));
    out.push('\n');
    out.push_str(&render_ablation("LR offset strategies (Witt et al.)", &ablate_lr_offsets(seed, frac)));
    out.push('\n');
    out.push_str(&render_ablation("fixed vs adaptive k (§V)", &ablate_adaptive_k(seed, frac)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full ablations run in the bench target; unit tests exercise the
    // plumbing on the smaller eager-only workload via low seeds.

    #[test]
    fn offsets_matter() {
        let rows = ablate_offsets(42, 0.5);
        assert_eq!(rows.len(), 4);
        // offsets OFF must cost more retries (that is their purpose)
        let on = rows.iter().find(|r| r.0.contains("Selective / offsets ON")).unwrap();
        let off = rows.iter().find(|r| r.0.contains("Selective / offsets OFF")).unwrap();
        assert!(off.2 > on.2, "offsets off should retry more: {off:?} vs {on:?}");
    }

    #[test]
    fn render_contains_rows() {
        let rows = vec![("a".to_string(), 1.0, 0.5)];
        let s = render_ablation("t", &rows);
        assert!(s.contains("| a | 1.000 | 0.500 |"));
    }
}
