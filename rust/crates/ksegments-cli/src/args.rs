//! Hand-rolled argument parsing for the `ksegments` binary (the
//! offline crate cache has no clap), plus the `schedule` subcommand's
//! typed argument bundle.
//!
//! Extracted from `main.rs` so the parsing rules are unit-testable:
//! [`Args::from_vec`] is the pure core ([`Args::parse`] just feeds it
//! `std::env::args`), and [`parse_sched_cli`] / [`methods_arg`] carry
//! all the validation that used to be inlined in the command handlers.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use ksegments::bench_harness::FitterChoice;

/// Hand-rolled `--key value` / `--flag` / positional parser.
pub struct Args {
    pub cmd: String,
    /// Last value per key (`--seed 1 --seed 2` keeps 2).
    pub kv: BTreeMap<String, String>,
    /// Every `--key value` pair in argv order, for repeatable keys
    /// like `bench --area sched --area replay`.
    pub pairs: Vec<(String, String)>,
    pub flags: Vec<String>,
    /// Positional arguments (only `ingest` accepts one: its DIR).
    pub pos: Vec<String>,
}

impl Args {
    /// Parse the process argv (everything after the program name).
    pub fn parse() -> Args {
        Args::from_vec(std::env::args().skip(1).collect())
    }

    /// Parse an explicit argument vector: the first element is the
    /// subcommand, the rest are `--key value` pairs, `--flag`s (a
    /// `--key` with no following value, or followed by another
    /// `--option`), and positionals. Never fails: validation belongs
    /// to the typed accessors and per-command parsers.
    pub fn from_vec(argv: Vec<String>) -> Args {
        let mut argv = argv.into_iter();
        let cmd = argv.next().unwrap_or_default();
        let mut kv = BTreeMap::new();
        let mut pairs = Vec::new();
        let mut flags = Vec::new();
        let mut pos = Vec::new();
        let rest: Vec<String> = argv.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let Some(key) = a.strip_prefix("--") else {
                pos.push(a.clone());
                i += 1;
                continue;
            };
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(key.to_string(), rest[i + 1].clone());
                pairs.push((key.to_string(), rest[i + 1].clone()));
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Args { cmd, kv, pairs, flags, pos }
    }

    /// All values given for a repeatable key, in argv order.
    pub fn all(&self, key: &str) -> Vec<String> {
        self.pairs.iter().filter(|(k, _)| k == key).map(|(_, v)| v.clone()).collect()
    }

    pub fn seed(&self) -> u64 {
        self.kv.get("seed").and_then(|s| s.parse().ok()).unwrap_or(42)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn fitter(&self) -> FitterChoice {
        if self.flag("xla") {
            FitterChoice::Xla
        } else {
            FitterChoice::Native
        }
    }

    pub fn workers(&self) -> usize {
        self.kv
            .get("workers")
            .and_then(|s| s.parse().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(ksegments::sim::default_workers)
    }

    pub fn shards(&self) -> usize {
        self.kv
            .get("shards")
            .and_then(|s| s.parse().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(4)
    }
}

/// Resolve the fig7/report/replay `--method` selection (default
/// "all"): either the whole roster or a comma list of method keys.
pub fn methods_arg(args: &Args) -> Result<Vec<&'static str>> {
    let sel = args.kv.get("method").map(String::as_str).unwrap_or("all");
    ksegments::bench_harness::resolve_methods(sel).map_err(|e| anyhow!(e))
}

/// Axes shared by the independent-arrivals and DAG schedule modes.
pub struct SchedCliArgs {
    pub n_nodes: usize,
    pub node_gib: f64,
    pub arrival: f64,
    pub policies: Vec<ksegments::sched::ReservationPolicy>,
    pub method: String,
    /// Node failures per second (0 = injection off).
    pub fail_rate: f64,
    pub preempt: bool,
    pub autoscale: Option<ksegments::sched::AutoscaleConfig>,
}

impl SchedCliArgs {
    /// Copy the adversity flags into a scheduling config.
    pub fn apply_failure_domains(&self, cfg: &mut ksegments::sched::SchedConfig) {
        use ksegments::units::Seconds;
        cfg.fail_mtbf = Seconds(if self.fail_rate > 0.0 { 1.0 / self.fail_rate } else { 0.0 });
        cfg.preempt = self.preempt;
        cfg.autoscale = self.autoscale;
    }

    /// Human-readable suffix for the run banner ("" when all off).
    pub fn adversity_summary(&self) -> String {
        let mut out = String::new();
        if self.fail_rate > 0.0 {
            out.push_str(&format!(" fail-rate={}/s", self.fail_rate));
        }
        if self.preempt {
            out.push_str(" preempt");
        }
        if let Some(a) = self.autoscale {
            out.push_str(&format!(" autoscale(lag={}s)", a.lag.0));
        }
        out
    }
}

pub fn parse_sched_cli(args: &Args) -> Result<SchedCliArgs> {
    use ksegments::sched::{AutoscaleConfig, ReservationPolicy};
    use ksegments::units::Seconds;
    let n_nodes: usize = args
        .kv
        .get("nodes")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);
    if n_nodes == 0 {
        bail!("--nodes must be at least 1");
    }
    let node_gib: f64 = args
        .kv
        .get("node-gib")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(32.0);
    let arrival: f64 = args
        .kv
        .get("arrival")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(5.0);
    let policy_arg = args.kv.get("policy").map(String::as_str).unwrap_or("both");
    let policies: Vec<ReservationPolicy> = match policy_arg {
        "both" => vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise],
        p => vec![ReservationPolicy::parse(p)
            .ok_or_else(|| anyhow!("unknown policy {p:?} (static|segment|both)"))?],
    };
    let method = args
        .kv
        .get("method")
        .map(String::as_str)
        .unwrap_or("ksegments-selective")
        .to_string();
    let fail_rate: f64 = args
        .kv
        .get("fail-rate")
        .map(|s| s.parse())
        .transpose()
        .context("--fail-rate takes failures per second, e.g. 0.1")?
        .unwrap_or(0.0);
    if fail_rate < 0.0 || !fail_rate.is_finite() {
        bail!("--fail-rate must be a finite rate >= 0 (failures per second)");
    }
    let preempt = args.flag("preempt");
    // `--autoscale` enables with the default 30 s lag;
    // `--autoscale SECS` overrides the provisioning lag
    let autoscale = if let Some(s) = args.kv.get("autoscale") {
        let lag: f64 = s
            .parse()
            .context("--autoscale takes an optional provisioning lag in seconds")?;
        if lag < 0.0 || !lag.is_finite() {
            bail!("--autoscale lag must be a finite number of seconds >= 0");
        }
        Some(AutoscaleConfig { lag: Seconds(lag), ..AutoscaleConfig::default() })
    } else if args.flag("autoscale") {
        Some(AutoscaleConfig::default())
    } else {
        None
    };
    Ok(SchedCliArgs {
        n_nodes,
        node_gib,
        arrival,
        policies,
        method,
        fail_rate,
        preempt,
        autoscale,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(words: &[&str]) -> Args {
        Args::from_vec(words.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn parses_kv_flags_and_positionals() {
        let a = argv(&["ingest", "traces/run1", "--out", "t.jsonl", "--preempt"]);
        assert_eq!(a.cmd, "ingest");
        assert_eq!(a.pos, vec!["traces/run1".to_string()]);
        assert_eq!(a.kv.get("out").map(String::as_str), Some("t.jsonl"));
        assert!(a.flag("preempt"));
        assert!(!a.flag("out"), "a key with a value is not a flag");
    }

    #[test]
    fn repeatable_keys_keep_argv_order_and_last_wins_in_kv() {
        let a = argv(&["bench", "--area", "sched", "--area", "replay", "--seed", "7"]);
        assert_eq!(a.all("area"), vec!["sched".to_string(), "replay".to_string()]);
        assert_eq!(a.kv.get("area").map(String::as_str), Some("replay"));
        assert_eq!(a.seed(), 7);
    }

    #[test]
    fn missing_value_demotes_key_to_flag() {
        // `--nodes` with no value (or followed by another option) is
        // recorded as a flag, so the typed accessor falls back to its
        // default instead of eating the next option as a value.
        let a = argv(&["schedule", "--nodes", "--preempt"]);
        assert!(a.flag("nodes"));
        assert!(a.kv.get("nodes").is_none());
        let cli = parse_sched_cli(&a).unwrap();
        assert_eq!(cli.n_nodes, 2, "default cluster size");
        assert!(cli.preempt);
    }

    #[test]
    fn sched_defaults_and_overrides() {
        let cli = parse_sched_cli(&argv(&["schedule"])).unwrap();
        assert_eq!(cli.n_nodes, 2);
        assert_eq!(cli.node_gib, 32.0);
        assert_eq!(cli.arrival, 5.0);
        assert_eq!(cli.policies.len(), 2, "--policy both is the default");
        assert_eq!(cli.method, "ksegments-selective");
        assert_eq!(cli.fail_rate, 0.0);
        assert!(cli.autoscale.is_none());

        let cli = parse_sched_cli(&argv(&[
            "schedule", "--nodes", "4", "--policy", "segment", "--fail-rate", "0.01",
            "--autoscale", "10",
        ]))
        .unwrap();
        assert_eq!(cli.n_nodes, 4);
        assert_eq!(cli.policies.len(), 1);
        assert_eq!(cli.fail_rate, 0.01);
        assert_eq!(cli.autoscale.unwrap().lag.0, 10.0);
    }

    #[test]
    fn unknown_policy_is_rejected() {
        let err = parse_sched_cli(&argv(&["schedule", "--policy", "bogus"])).unwrap_err();
        assert!(err.to_string().contains("unknown policy"), "{err}");
    }

    #[test]
    fn malformed_values_error_with_context() {
        let err = parse_sched_cli(&argv(&["schedule", "--fail-rate", "often"])).unwrap_err();
        assert!(format!("{err:#}").contains("--fail-rate"), "{err:#}");
        let err = parse_sched_cli(&argv(&["schedule", "--autoscale", "-5"])).unwrap_err();
        assert!(err.to_string().contains("autoscale lag"), "{err}");
        assert!(parse_sched_cli(&argv(&["schedule", "--nodes", "0"])).is_err());
    }

    #[test]
    fn method_selection_parses_lists() {
        let all = methods_arg(&argv(&["fig7"])).unwrap();
        assert!(all.len() >= 8, "default \"all\" resolves the whole roster");

        let some =
            methods_arg(&argv(&["fig7", "--method", "ksegments-selective, ensemble"])).unwrap();
        assert_eq!(some, vec!["ksegments-selective", "ensemble"]);

        assert!(methods_arg(&argv(&["fig7", "--method", "bogus"])).is_err());
    }
}
