//! The monitoring pipeline: cgroup-style interval sampling into the
//! TSDB, plus file-event metadata (paper §IV-A).
//!
//! In the paper, a Nextflow extension polls the Docker API (cpuacct,
//! memory, blkio cgroup controllers) every 2 s and writes to InfluxDB;
//! a file monitor records input counts/sizes. Here the "container" is
//! a ground-truth usage curve from the workload generator; the sampler
//! discretizes it at the monitoring interval, stores the points, and
//! reconstructs the [`UsageSeries`] the predictor trains on.

use crate::trace::UsageSeries;
use crate::tsdb::{Point, SeriesKey, TsDb};

/// Default monitoring interval (paper: "comes with a default of two
/// seconds").
pub const DEFAULT_INTERVAL_S: f64 = 2.0;

/// File-event metadata captured at task submission: what the predictor
/// uses as its independent variable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FileStats {
    pub n_input_files: u32,
    pub total_input_mib: f64,
}

impl FileStats {
    pub fn single(total_input_mib: f64) -> FileStats {
        FileStats { n_input_files: 1, total_input_mib }
    }
}

/// Interval sampler over a task's live memory usage.
#[derive(Debug, Clone, Copy)]
pub struct Sampler {
    pub interval_s: f64,
}

impl Default for Sampler {
    fn default() -> Self {
        Sampler { interval_s: DEFAULT_INTERVAL_S }
    }
}

impl Sampler {
    pub fn new(interval_s: f64) -> Sampler {
        assert!(interval_s > 0.0, "non-positive monitoring interval");
        Sampler { interval_s }
    }

    /// Sample a run's usage function over `[0, runtime_s)` into the
    /// TSDB under `key`, returning the number of points written.
    ///
    /// `usage` is the live usage in MiB at a given time — in the
    /// simulator that's a closure over the ground-truth curve; in a
    /// real deployment it would be the cgroup `memory.usage_in_bytes`
    /// read.
    pub fn sample_run<F: FnMut(f64) -> f64>(
        &self,
        db: &mut TsDb,
        key: &SeriesKey,
        runtime_s: f64,
        mut usage: F,
    ) -> usize {
        let n = (runtime_s / self.interval_s).ceil().max(1.0) as usize;
        for i in 0..n {
            let t = i as f64 * self.interval_s;
            db.append(key, Point { t, value: usage(t) });
        }
        n
    }

    /// Reconstruct the training series from stored points.
    pub fn series_from_db(&self, db: &TsDb, key: &SeriesKey) -> UsageSeries {
        let values: Vec<f64> = db.get(key).iter().map(|p| p.value).collect();
        UsageSeries::new(self.interval_s, values)
    }

    /// Sample the full cgroup controller set the paper's extension
    /// reads (§IV-A: cpuacct, memory, blkio) for one run.
    ///
    /// Memory comes from the live usage function; the cpu and blkio
    /// channels are derived models (cpu utilisation tracks how hard the
    /// task is working its resident set; blkio spreads the input volume
    /// over the runtime) — they exercise the multi-metric storage path
    /// end to end, which is what the k-Segments predictor's "or CPU
    /// usage, or file events" extensibility claim needs.
    pub fn sample_run_all_controllers<F: FnMut(f64) -> f64>(
        &self,
        db: &mut TsDb,
        task_type: &str,
        run_id: u64,
        runtime_s: f64,
        input_mib: f64,
        mut mem_usage: F,
    ) -> usize {
        let n = (runtime_s / self.interval_s).ceil().max(1.0) as usize;
        let mem_key = SeriesKey::mem(task_type, run_id);
        let cpu_key = SeriesKey {
            task_type: task_type.to_string(),
            run_id,
            metric: "cpu_frac".to_string(),
        };
        let io_key = SeriesKey {
            task_type: task_type.to_string(),
            run_id,
            metric: "blkio_mib".to_string(),
        };
        let mut prev_mem = 0.0;
        for i in 0..n {
            let t = i as f64 * self.interval_s;
            let mem = mem_usage(t);
            db.append(&mem_key, Point { t, value: mem });
            // cpu: busy while memory is moving; idles on plateaus
            let delta = (mem - prev_mem).abs();
            let cpu = (0.25 + delta / mem.max(1.0)).min(1.0);
            db.append(&cpu_key, Point { t, value: cpu });
            // blkio: cumulative bytes read, front-loaded input scan
            let frac = ((i + 1) as f64 / n as f64).min(1.0);
            db.append(&io_key, Point { t, value: input_mib * frac.sqrt() });
            prev_mem = mem;
        }
        3 * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_writes_expected_points() {
        let mut db = TsDb::new();
        let key = SeriesKey::mem("wf/t", 0);
        let s = Sampler::new(2.0);
        let n = s.sample_run(&mut db, &key, 10.0, |t| t * 100.0);
        assert_eq!(n, 5);
        let pts = db.get(&key);
        assert_eq!(pts.len(), 5);
        assert_eq!(pts[0], Point { t: 0.0, value: 0.0 });
        assert_eq!(pts[4], Point { t: 8.0, value: 800.0 });
    }

    #[test]
    fn partial_last_interval_still_sampled() {
        let mut db = TsDb::new();
        let key = SeriesKey::mem("wf/t", 1);
        let n = Sampler::new(2.0).sample_run(&mut db, &key, 5.0, |_| 1.0);
        assert_eq!(n, 3); // ceil(5/2)
    }

    #[test]
    fn tiny_run_gets_one_sample() {
        let mut db = TsDb::new();
        let key = SeriesKey::mem("wf/t", 2);
        let n = Sampler::new(2.0).sample_run(&mut db, &key, 0.3, |_| 7.0);
        assert_eq!(n, 1);
    }

    #[test]
    fn series_roundtrip() {
        let mut db = TsDb::new();
        let key = SeriesKey::mem("wf/t", 3);
        let s = Sampler::new(2.0);
        s.sample_run(&mut db, &key, 6.0, |t| 10.0 + t);
        let series = s.series_from_db(&db, &key);
        assert_eq!(series.samples(), &[10.0, 12.0, 14.0]);
        assert_eq!(series.interval().0, 2.0);
    }

    #[test]
    fn all_controllers_sampled() {
        let mut db = TsDb::new();
        let s = Sampler::new(2.0);
        let n = s.sample_run_all_controllers(&mut db, "wf/t", 9, 10.0, 500.0, |t| 100.0 + t);
        assert_eq!(n, 15); // 3 controllers x 5 samples
        assert_eq!(db.n_series(), 3);
        assert_eq!(db.get(&SeriesKey::mem("wf/t", 9)).len(), 5);
        let cpu = SeriesKey { task_type: "wf/t".into(), run_id: 9, metric: "cpu_frac".into() };
        assert!(db.get(&cpu).iter().all(|p| (0.0..=1.0).contains(&p.value)));
        let io = SeriesKey { task_type: "wf/t".into(), run_id: 9, metric: "blkio_mib".into() };
        let io_pts = db.get(&io);
        // cumulative and capped by the input volume
        assert!(io_pts.windows(2).all(|w| w[1].value >= w[0].value));
        assert!(io_pts.last().unwrap().value <= 500.0 + 1e-9);
    }

    #[test]
    fn default_interval_is_paper_default() {
        assert_eq!(Sampler::default().interval_s, 2.0);
    }

    #[test]
    fn file_stats_helper() {
        let f = FileStats::single(123.0);
        assert_eq!(f.n_input_files, 1);
        assert_eq!(f.total_input_mib, 123.0);
    }
}
