//! Trace persistence: CSV (one row per sample, like the paper's
//! published k-Segments-traces repository) and JSON-lines (one object
//! per run, convenient for tooling).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{TaskRun, Trace, UsageSeries};
use crate::units::{MemMiB, Seconds};
use crate::util::json::Json;

/// Write a trace as JSON lines: a `default` record per task type with a
/// configured default, then a `run` record per execution.
pub fn write_trace_jsonl(trace: &Trace, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).context("creating jsonl trace")?);
    for ty in trace.task_types().map(String::from).collect::<Vec<_>>() {
        if let Some(mem) = trace.default_alloc(&ty) {
            let rec = Json::obj(vec![
                ("kind", "default".into()),
                ("task_type", ty.as_str().into()),
                ("default_mib", mem.0.into()),
            ]);
            writeln!(w, "{rec}")?;
        }
        for run in trace.runs_of(&ty) {
            let rec = Json::obj(vec![
                ("kind", "run".into()),
                ("task_type", run.task_type.as_str().into()),
                ("seq", run.seq.into()),
                ("input_mib", run.input_mib.into()),
                ("runtime_s", run.runtime.0.into()),
                ("interval_s", run.series.interval().0.into()),
                ("samples_mib", Json::arr_f64(run.series.samples())),
            ]);
            writeln!(w, "{rec}")?;
        }
    }
    Ok(())
}

/// Read a JSONL trace written by [`write_trace_jsonl`].
pub fn read_trace_jsonl(path: &Path) -> Result<Trace> {
    let r = BufReader::new(File::open(path).context("opening jsonl trace")?);
    let mut trace = Trace::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let rec = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("jsonl line {}: {}", lineno + 1, e))?;
        let kind = rec.get("kind").as_str().unwrap_or("");
        let ty = rec
            .get("task_type")
            .as_str()
            .context("missing task_type")?
            .to_string();
        match kind {
            "default" => {
                let mem = rec.get("default_mib").as_f64().context("default_mib")?;
                trace.set_default(&ty, MemMiB(mem));
            }
            "run" => {
                let samples: Vec<f64> = rec
                    .get("samples_mib")
                    .as_arr()
                    .context("samples_mib")?
                    .iter()
                    .map(|v| v.as_f64().context("non-numeric sample"))
                    .collect::<Result<_>>()?;
                trace.push(TaskRun {
                    task_type: ty,
                    input_mib: rec.get("input_mib").as_f64().context("input_mib")?,
                    runtime: Seconds(rec.get("runtime_s").as_f64().context("runtime_s")?),
                    series: UsageSeries::new(
                        rec.get("interval_s").as_f64().context("interval_s")?,
                        samples,
                    ),
                    seq: rec.get("seq").as_u64().context("seq")?,
                });
            }
            other => bail!("jsonl line {}: unknown kind {:?}", lineno + 1, other),
        }
    }
    trace.sort();
    Ok(trace)
}

/// Write a trace as CSV with one row per monitoring sample:
/// `task_type,seq,input_mib,runtime_s,interval_s,sample_idx,mem_mib`.
pub fn write_trace_csv(trace: &Trace, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).context("creating csv trace")?);
    writeln!(w, "task_type,seq,input_mib,runtime_s,interval_s,sample_idx,mem_mib")?;
    for ty in trace.task_types().map(String::from).collect::<Vec<_>>() {
        for run in trace.runs_of(&ty) {
            for (i, v) in run.series.samples().iter().enumerate() {
                writeln!(
                    w,
                    "{},{},{},{},{},{},{}",
                    run.task_type,
                    run.seq,
                    run.input_mib,
                    run.runtime.0,
                    run.series.interval().0,
                    i,
                    v
                )?;
            }
        }
    }
    Ok(())
}

/// Read a CSV trace written by [`write_trace_csv`].
pub fn read_trace_csv(path: &Path) -> Result<Trace> {
    let r = BufReader::new(File::open(path).context("opening csv trace")?);
    let mut lines = r.lines();
    let header = lines.next().transpose()?.unwrap_or_default();
    if !header.starts_with("task_type,seq,") {
        bail!("unrecognized trace csv header: {header:?}");
    }
    // accumulate rows into runs keyed by (type, seq)
    let mut current: Option<(String, u64, f64, f64, f64, Vec<f64>)> = None;
    let mut trace = Trace::new();
    fn flush(cur: &mut Option<(String, u64, f64, f64, f64, Vec<f64>)>, trace: &mut Trace) {
        if let Some((ty, seq, input, rt, iv, samples)) = cur.take() {
            trace.push(TaskRun {
                task_type: ty,
                input_mib: input,
                runtime: Seconds(rt),
                series: UsageSeries::new(iv, samples),
                seq,
            });
        }
    }
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 7 {
            bail!("csv line {}: expected 7 fields, got {}", lineno + 2, f.len());
        }
        let (ty, seq) = (f[0].to_string(), f[1].parse::<u64>()?);
        let (input, rt, iv) = (f[2].parse()?, f[3].parse()?, f[4].parse()?);
        let mem: f64 = f[6].parse()?;
        match &mut current {
            Some((cty, cseq, _, _, _, samples)) if *cty == ty && *cseq == seq => {
                samples.push(mem)
            }
            _ => {
                flush(&mut current, &mut trace);
                current = Some((ty, seq, input, rt, iv, vec![mem]));
            }
        }
    }
    flush(&mut current, &mut trace);
    trace.sort();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let mut t = Trace::new();
        t.set_default("wf/a", MemMiB(4096.0));
        for seq in 0..3u64 {
            t.push(TaskRun {
                task_type: "wf/a".into(),
                input_mib: 100.0 + seq as f64,
                runtime: Seconds(6.0),
                series: UsageSeries::new(2.0, vec![1.0, 5.0 + seq as f64, 2.0]),
                seq,
            });
        }
        t.push(TaskRun {
            task_type: "wf/b".into(),
            input_mib: 9.0,
            runtime: Seconds(2.0),
            series: UsageSeries::new(2.0, vec![7.0]),
            seq: 3,
        });
        t
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let t = sample_trace();
        write_trace_jsonl(&t, &path).unwrap();
        let back = read_trace_jsonl(&path).unwrap();
        assert_eq!(back.n_types(), 2);
        assert_eq!(back.n_runs(), 4);
        assert_eq!(back.runs_of("wf/a"), t.runs_of("wf/a"));
        assert_eq!(back.default_alloc("wf/a"), Some(MemMiB(4096.0)));
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        let t = sample_trace();
        write_trace_csv(&t, &path).unwrap();
        let back = read_trace_csv(&path).unwrap();
        assert_eq!(back.n_runs(), 4);
        assert_eq!(back.runs_of("wf/b")[0].series.samples(), &[7.0]);
        // CSV does not carry defaults
        assert_eq!(back.default_alloc("wf/a"), None);
    }

    #[test]
    fn csv_rejects_bad_header() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "nope\n1,2,3\n").unwrap();
        assert!(read_trace_csv(&path).is_err());
    }

    #[test]
    fn jsonl_rejects_unknown_kind() {
        let dir = std::env::temp_dir().join("ksegments_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"kind\":\"wat\",\"task_type\":\"x\"}\n").unwrap();
        assert!(read_trace_jsonl(&path).is_err());
    }
}
